//! Inspect every exported bundle of a model: quant modes per layer,
//! resident sizes, clip ratios, reconstruction stats — the "what did the
//! pipeline actually do" tour of the MergeQuant method (paper §4).
//!
//! ```sh
//! cargo run --release --example quantize_inspect [-- --model tiny-llama-s]
//! ```

use mergequant::artifacts_dir;
use mergequant::cli::Args;
use mergequant::engine::{Linear, QModel, QuantMode};

fn describe(lin: &Linear) -> String {
    match lin {
        Linear::Fp { n, j, .. } => format!("fp32 ({n}×{j})"),
        Linear::Quant { qw, mode } => {
            let m = match mode {
                QuantMode::Static => "static".into(),
                QuantMode::TensorStatic { a_scale, .. } =>
                    format!("tensor-static a_scale={a_scale:.4}"),
                QuantMode::Dynamic { a_clip, hadamard, .. } => format!(
                    "dynamic clip={a_clip:.2}{}",
                    if *hadamard { " +hadamard" } else { "" }),
            };
            format!("w{}b{} {} ({}×{}, {:.1} KB)", qw.bits,
                    if qw.zero.is_some() { "-asym" } else { "" }, m,
                    qw.n, qw.j, qw.resident_bytes() as f64 / 1e3)
        }
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let model = args.get_or("model", "tiny-llama-s");
    let dir = artifacts_dir().join("models").join(model);
    if !dir.exists() {
        eprintln!("run `make artifacts` first ({} missing)", dir.display());
        return Ok(());
    }
    let mut bundles: Vec<_> = std::fs::read_dir(&dir)?
        .filter_map(|e| e.ok())
        .filter(|e| e.path().extension().is_some_and(|x| x == "qmod"))
        .map(|e| e.path())
        .collect();
    bundles.sort();
    println!("{} bundles under {}", bundles.len(), dir.display());
    for path in bundles {
        let qm = QModel::load(&path)?;
        println!("\n== {} ==", qm.method);
        println!("  weights resident: {:.2} MB",
                 qm.weight_bytes() as f64 / 1e6);
        let l = &qm.layers[0];
        println!("  layer 0:");
        for (name, lin) in [("q", &l.q), ("k", &l.k), ("v", &l.v),
                            ("o", &l.o), ("gate", &l.gate), ("up", &l.up),
                            ("down", &l.down)] {
            println!("    {name:<5} {}", describe(lin));
        }
        if let Some(qmax) = l.attn_norm.quant_qmax {
            let recon = l.attn_norm.recon_idx.as_ref();
            let dup = recon.map_or(0, |idx| {
                let mut seen = std::collections::HashSet::new();
                idx.iter().filter(|&&i| !seen.insert(i)).count()
            });
            println!("    attn_norm: merged γ/s multiplier (qmax={qmax}), \
                      reconstruction gather with {dup} duplicated channels");
        } else {
            println!("    attn_norm: plain fp32 RMSNorm");
        }
    }
    Ok(())
}
