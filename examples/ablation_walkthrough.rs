//! Walk the paper's Table-4 ablation live: evaluate each pipeline stage's
//! bundle on a PPL slice and print the improvement chain
//! (QuaRot&static → +QSM → +clipping → +LoRA), plus the speed cost of
//! the dynamic baseline it replaces.
//!
//! ```sh
//! cargo run --release --example ablation_walkthrough
//! ```

use mergequant::artifacts_dir;
use mergequant::engine::{Engine, KvCache, QModel, Workspace};
use mergequant::eval::{corpus, perplexity};

fn main() -> anyhow::Result<()> {
    let model = "tiny-llama3";
    let rows = [
        ("FP16 reference        ", "fp16"),
        ("QuaRot & per-tensor   ", "quarot_static"),
        ("+ QSM (per-channel)   ", "mq_qsm_only"),
        ("+ adaptive clipping   ", "mq_qsm_clip"),
        ("+ LoRA compensation   ", "mergequant"),
    ];
    let dir = artifacts_dir().join("models").join(model);
    if !dir.join("mergequant.qmod").exists() {
        eprintln!("run `make artifacts` first");
        return Ok(());
    }
    let toks = corpus::val_stream(&artifacts_dir(), "synth-wiki")?;
    let slice = &toks[..6144.min(toks.len())];
    println!("Table-4 ablation on {model} (PPL over {} tokens):",
             slice.len());
    let mut prev: Option<f64> = None;
    for (label, method) in rows {
        let path = dir.join(format!("{method}.qmod"));
        if !path.exists() {
            println!("  {label}  [bundle missing]");
            continue;
        }
        let engine = Engine::new(QModel::load(&path)?);
        let ppl = perplexity(&engine, slice, 256);
        let delta = prev.map_or(String::new(),
                                |p| format!("  (Δ {:+.3})", ppl - p));
        println!("  {label} ppl = {ppl:8.3}{delta}");
        prev = Some(ppl);
    }

    // Speed sidebar: what the static path buys on this model.
    println!("\ndecode-speed sidebar (batch 4, 32 steps):");
    for method in ["fp16", "rtn", "mergequant"] {
        let path = dir.join(format!("{method}.qmod"));
        if !path.exists() {
            continue;
        }
        let engine = Engine::new(QModel::load(&path)?);
        let cfg = engine.config().clone();
        let mut ws = Workspace::new();
        let mut caches: Vec<KvCache> = (0..4)
            .map(|_| {
                let mut c = KvCache::new(cfg.n_layers, 96, cfg.d_model);
                engine.prefill(&[3, 4, 5, 6], &mut c, &mut ws).expect("prefill");
                c
            })
            .collect();
        let t0 = std::time::Instant::now();
        let toks = vec![5u32; 4];
        for _ in 0..32 {
            let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
            engine.decode_batch(&toks, &mut refs, &mut ws).expect("decode");
        }
        let dt = t0.elapsed().as_secs_f64();
        println!("  {method:<12} {:.0} tok/s", 4.0 * 32.0 / dt);
    }
    Ok(())
}
