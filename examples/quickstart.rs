//! Quickstart: load a MergeQuant bundle, inspect it, and generate text.
//!
//! ```sh
//! make artifacts                      # once (build-time Python)
//! cargo run --release --example quickstart
//! ```

use mergequant::artifacts_dir;
use mergequant::engine::{memory::account_model, Engine, QModel};

fn main() -> anyhow::Result<()> {
    let bundle = artifacts_dir()
        .join("models/tiny-llama-s/mergequant.qmod");
    if !bundle.exists() {
        eprintln!("run `make artifacts` first ({} missing)",
                  bundle.display());
        return Ok(());
    }

    // 1. Load the W4A4 statically-quantized bundle.
    let model = QModel::load(&bundle)?;
    let cfg = model.config.clone();
    println!("loaded {} ({}): d={} layers={} vocab={}",
             cfg.name, model.method, cfg.d_model, cfg.n_layers, cfg.vocab);
    println!("resident weights: {:.2} MB (int4-packed)",
             model.weight_bytes() as f64 / 1e6);
    let mb = account_model(&model, 1, 2048, mergequant::engine::KvDtype::F32);
    println!("decode memory (batch 1, seq 2048): {:.2} MB total",
             mb.total() as f64 / 1e6);

    // 2. Greedy generation — the static path runs zero Quant/DeQuant steps.
    let engine = Engine::new(model);
    let prompt: Vec<u32> = vec![1, 17, 42, 99, 7, 256];
    let t0 = std::time::Instant::now();
    let completion = engine.generate(&prompt, 48, 128)?;
    let dt = t0.elapsed();
    println!("prompt     : {prompt:?}");
    println!("completion : {completion:?}");
    println!("decode rate: {:.0} tok/s",
             completion.len() as f64 / dt.as_secs_f64());

    // 2b. Seeded sampling (generation API v2): same decode path, token
    // selection through the counter-based top-k/top-p sampler — a fixed
    // seed replays the identical stream on any thread count.
    let sampler = mergequant::engine::Sampler::new(0.8, 40, 0.95, 7);
    let sampled = engine.generate_seeded(
        &prompt, 48, 128, mergequant::engine::KvDtype::F32, &sampler)?;
    println!("sampled    : {sampled:?} (T=0.8 top_k=40 top_p=0.95 seed=7)");

    // 3. Perplexity on the held-out synthetic corpus.
    let toks = mergequant::eval::corpus::val_stream(&artifacts_dir(),
                                                    "synth-wiki")?;
    let ppl = mergequant::eval::perplexity(&engine, &toks[..4096], 256);
    println!("ppl[synth-wiki] = {ppl:.3}");
    Ok(())
}
