//! End-to-end serving driver (the DESIGN.md §9 validation run), on the
//! generation API v2.
//!
//! Part 1 — **API demo** (runs even without artifacts, on synthetic
//! weights): one server, three concurrent requests through
//! `Server::generate` / `RequestHandle`:
//!   * a long-running request that is cancelled mid-stream,
//!   * a sampled request (temperature/top-k/top-p, fixed seed) printed
//!     token by token as its frames arrive,
//!   * a greedy request that pends until the cancellation returns its KV
//!     slab (slab reuse by a later admission) and whose tokens must match
//!     the seed greedy golden (`Engine::generate`).
//!
//! Part 1c — **router demo** (synthetic fallback too): three chat
//! sessions take three turns across a two-replica router fleet
//! (DESIGN.md §16) — session affinity keeps follow-up turns on warm
//! prefix blocks, a mid-run drain retires and respawns a replica, and
//! every stream is golden-checked against `Engine::generate`.
//!
//! Part 2 — **fleet run** (needs `make artifacts`): a closed-loop
//! Poisson client fleet speaking the v2 NDJSON streaming protocol at the
//! TCP gateway, for the FP16 and MergeQuant bundles, reporting
//! latency/TTFT/throughput and the serving-level speedup.
//!
//! ```sh
//! cargo run --release --example serve_e2e [-- --requests 32 --clients 4 --threads 4]
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use mergequant::artifacts_dir;
use mergequant::bench::synthetic_model;
use mergequant::cli::Args;
use mergequant::coordinator::server::TcpGateway;
use mergequant::coordinator::{
    Event, FinishReason, GenerationParams, Request, Router,
    RouterConfig, Scheduler, SchedulerConfig, Server,
};
use mergequant::engine::{Engine, QModel};
use mergequant::util::json::Json;
use mergequant::util::rng::Rng;
use mergequant::util::stats::summarize;

/// Load the bundle when artifacts exist, otherwise fall back to the
/// (deterministic) synthetic model of the same method.
fn build_model(method: &str) -> anyhow::Result<(QModel, bool)> {
    let bundle = artifacts_dir()
        .join(format!("models/tiny-llama-s/{method}.qmod"));
    if bundle.exists() {
        Ok((QModel::load(&bundle)?, true))
    } else {
        Ok((synthetic_model(method, 64, 128, 2, 96), false))
    }
}

// ---------------------------------------------------------------------
// Part 1: generate / RequestHandle / cancel demo
// ---------------------------------------------------------------------

fn api_demo(threads: usize) -> anyhow::Result<()> {
    let (model, real) = build_model("mergequant")?;
    println!("== generation API v2 demo ({}) ==",
             if real { "mergequant bundle" } else { "synthetic weights" });
    // Reference engine for the greedy golden (identical weights).
    let golden_engine = Engine::new(build_model("mergequant")?.0);
    let greedy_prompt: Vec<u32> = vec![1, 17, 42, 5];
    let golden = golden_engine.generate(&greedy_prompt, 24, 2048)?;

    // Two batch slots for three requests: the third admission *requires*
    // the cancellation below to free a slot (its KV blocks come back to
    // the paged arena on the same iteration — DESIGN.md §13). The radix
    // prefix cache rides along (DESIGN.md §14): these prompts share no
    // prefix, so it must change nothing — but the report line below
    // carries its hit/eviction counters end to end.
    let server = Server::start(
        Engine::new(model),
        SchedulerConfig {
            max_batch: 2,
            kv_slabs: 2,
            kv_block: 32,
            kv_blocks: 0,
            max_seq: 2048,
            max_prefills_per_iter: 2,
            queue_cap: 16,
            prefill_chunk: 0,
            threads,
            kv_dtype: mergequant::engine::KvDtype::F32,
            prefix_cache: true,
            prefix_cache_blocks: 64,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    );

    // (a) long-running victim — will be torn out of the batch.
    let h_victim = server
        .generate(vec![2, 4, 6, 8], GenerationParams::greedy(100_000))
        .map_err(anyhow::Error::msg)?;
    // (b) sampled request, streamed below.
    let h_sampled = server
        .generate(vec![3, 9, 12, 40], GenerationParams {
            max_new: 48,
            temperature: 0.8,
            top_k: 24,
            top_p: 0.95,
            seed: 7,
            ..GenerationParams::greedy(48)
        })
        .map_err(anyhow::Error::msg)?;
    // (c) greedy request — pends: both slabs are taken.
    let h_greedy = server
        .generate(greedy_prompt, GenerationParams::greedy(24))
        .map_err(anyhow::Error::msg)?;

    // Stream a few tokens from the victim, then cancel it. Its slab
    // comes back on the next scheduler iteration and admits (c).
    print!("victim  [id {}]:", h_victim.id());
    for _ in 0..4 {
        if let Some(Event::Token { token, .. }) = h_victim.recv() {
            print!(" {token}");
        }
    }
    h_victim.cancel();
    println!("  → cancel()");

    // Stream the sampled request token by token (the per-token cadence
    // MergeQuant's static decode path accelerates).
    print!("sampled [id {}]:", h_sampled.id());
    let sampled = loop {
        match h_sampled.recv() {
            Some(Event::Token { token, .. }) => print!(" {token}"),
            Some(Event::Done { response }) => break response,
            Some(Event::Error { response }) => {
                anyhow::bail!("sampled request failed: {:?}", response.error)
            }
            None => anyhow::bail!("event stream closed early"),
        }
    };
    println!("  ({} tokens, finish {})", sampled.tokens.len(),
             sampled.finish.as_str());

    let r_victim = h_victim.wait();
    assert_eq!(r_victim.finish, FinishReason::Cancelled);
    println!("victim finished: {} ({} tokens before teardown)",
             r_victim.finish.as_str(), r_victim.tokens.len());

    let r_greedy = h_greedy.wait();
    assert_eq!(r_greedy.tokens, golden,
               "greedy stream must match the seed golden");
    println!("greedy  [id {}]: {} tokens — matches Engine::generate \
              golden ✓ (admitted into the cancelled request's slab)",
             r_greedy.id, r_greedy.tokens.len());
    // The scheduler report line carries the paged-KV packing story —
    // kv_util (mean/peak used-token over allocated-block-token ratio),
    // the blocks_alloc/blocks_freed churn counters (DESIGN.md §13) —
    // and the prefix-cache counters (prefix_hit_rate=…, DESIGN.md §14).
    println!("scheduler: {}\n", server.shutdown());
    Ok(())
}

// ---------------------------------------------------------------------
// Part 1b: bursty mixed-priority preemption demo (DESIGN.md §15)
// ---------------------------------------------------------------------

/// A high-class burst lands on a dry block pool: the low-class decode
/// lane is preempted (its blocks handed to the newcomer), the burst is
/// served, and the victim resumes — streaming **bitwise** the tokens the
/// uninterrupted `Engine::generate` run produces. Driven synchronously
/// through `Scheduler::step` so the interleaving is deterministic (the
/// report line at the end is what CI greps `preemptions=` /
/// `slo_violations=` from).
fn preemption_demo(threads: usize) -> anyhow::Result<()> {
    let (model, real) = build_model("mergequant")?;
    println!("== priority preemption demo ({}) ==",
             if real { "mergequant bundle" } else { "synthetic weights" });
    // Golden: the low-class request run uninterrupted on its own engine.
    let low_prompt: Vec<u32> = (0..49).map(|i| 3 + (i * 5) % 90).collect();
    let golden = Engine::new(model).generate(&low_prompt, 12, 64)?;

    // Arena of exactly 4 blocks × 16 tokens: the 49-token low-class
    // prompt takes all four, so the high-class arrival finds the free
    // list empty and *must* preempt to be admitted.
    let mut sched = Scheduler::new(
        Engine::with_threads(build_model("mergequant")?.0, threads),
        SchedulerConfig {
            max_batch: 4,
            kv_slabs: 0,
            kv_block: 16,
            kv_blocks: 4,
            max_seq: 64,
            max_prefills_per_iter: 2,
            queue_cap: 16,
            prefill_chunk: 0,
            threads,
            kv_dtype: mergequant::engine::KvDtype::F32,
            prefix_cache: false,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    );
    // Low-class background request with an impossible deadline (counts
    // one SLO violation; deadlines are observational — DESIGN.md §15).
    sched.submit(Request::with_params(1, low_prompt, GenerationParams {
        priority: 0,
        deadline_ms: Some(0),
        ..GenerationParams::greedy(12)
    })).map_err(|r| anyhow::anyhow!("submit {} rejected", r.id))?;
    sched.step(); // prefill + first token: all 4 blocks held
    sched.step(); // second token
    // …the interactive burst arrives.
    sched.submit(Request::with_params(
        2, (0..16).map(|i| 5 + i * 3).collect(), GenerationParams {
            priority: 2,
            ..GenerationParams::greedy(8)
        })).map_err(|r| anyhow::anyhow!("submit {} rejected", r.id))?;
    let mut rs = sched.run_to_completion();
    rs.sort_by_key(|r| r.id);

    assert_eq!(sched.preemption_log(), &[1],
               "the class-0 lane must be the (only) victim");
    assert_eq!(rs[1].finish, FinishReason::Length, "burst must complete");
    assert_eq!(rs[0].finish, FinishReason::Length,
               "the victim resumes and finishes — never cache_full");
    assert_eq!(rs[0].tokens, golden,
               "preempt/resume must be bitwise invisible in the stream");
    println!("victim  [id 1]: preempted by the class-2 burst, resumed, \
              {} tokens — matches Engine::generate golden ✓",
             rs[0].tokens.len());
    println!("burst   [id 2]: class 2, {} tokens, admitted into the \
              victim's blocks", rs[1].tokens.len());
    println!("scheduler: {}\n", sched.metrics.report());
    Ok(())
}

// ---------------------------------------------------------------------
// Part 1b½: self-speculative decode demo (DESIGN.md §18)
// ---------------------------------------------------------------------

/// One greedy request decoded through the speculative lane: a
/// full-depth self-draft (`draft_layers: 0`) proposes `draft_k` tokens
/// per tick and the target verifies them in one All-rows span. The
/// stream must be **bitwise** the non-speculative `Engine::generate`
/// golden, the full-depth draft must be accepted wholesale
/// (acceptance_rate exactly 1.0 — the draft IS the target), and the
/// report line at the end is what CI greps `acceptance_rate=` from.
fn speculative_demo(threads: usize) -> anyhow::Result<()> {
    let (model, real) = build_model("mergequant")?;
    println!("== self-speculative decode demo ({}) ==",
             if real { "mergequant bundle" } else { "synthetic weights" });
    let prompt: Vec<u32> = (0..24).map(|i| 3 + (i * 7) % 90).collect();
    let golden = Engine::new(model).generate(&prompt, 16, 64)?;

    let mut sched = Scheduler::new(
        Engine::with_threads(build_model("mergequant")?.0, threads),
        SchedulerConfig {
            max_batch: 2,
            kv_slabs: 0,
            kv_block: 16,
            kv_blocks: 8,
            max_seq: 64,
            max_prefills_per_iter: 2,
            queue_cap: 16,
            prefill_chunk: 0,
            threads,
            kv_dtype: mergequant::engine::KvDtype::F32,
            prefix_cache: false,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: true,
            draft_k: 4,
            draft_layers: 0,
        },
    );
    sched.submit(Request::new(1, prompt, 16))
        .map_err(|r| anyhow::anyhow!("submit {} rejected", r.id))?;
    let rs = sched.run_to_completion();
    assert_eq!(rs.len(), 1);
    assert_eq!(rs[0].tokens, golden,
               "speculation must be bitwise invisible in the stream");
    assert!((sched.metrics.acceptance_rate() - 1.0).abs() < 1e-12,
            "a full-depth self-draft must be accepted wholesale");
    assert!(sched.metrics.tokens_per_forward() > 1.0,
            "speculation must beat one token per target forward");
    println!("greedy  [id 1]: {} tokens via draft_k=4 speculation — \
              matches Engine::generate golden ✓ ({:.2} tokens per \
              target forward)",
             rs[0].tokens.len(), sched.metrics.tokens_per_forward());
    println!("scheduler: {}\n", sched.metrics.report());
    Ok(())
}

// ---------------------------------------------------------------------
// Part 1c: replica-sharded router demo (DESIGN.md §16)
// ---------------------------------------------------------------------

/// Three chat sessions take three turns each across a two-replica
/// router fleet: session affinity keeps every follow-up turn on its
/// pinned replica (a warm prefix-cache hit), a mid-run drain retires
/// one replica — finishing its work, respawning it clean — without the
/// router ever refusing admissions, and every turn's tokens are
/// golden-checked against the uninterrupted `Engine::generate` run:
/// routing decides *placement*, never stream content. The router
/// report printed at the end is what CI greps `dispatch=` /
/// `affinity_hit_rate=` from.
fn router_demo(threads: usize) -> anyhow::Result<()> {
    let (model, real) = build_model("mergequant")?;
    println!("== router tier demo ({}) ==",
             if real { "mergequant bundle" } else { "synthetic weights" });
    let golden_engine = Engine::new(model);
    // Whole-box arena; `RouterConfig::per_replica` splits the 64 blocks
    // evenly across the two replicas (32 blocks × 16 tokens each).
    let cfg = SchedulerConfig {
        max_batch: 4,
        kv_slabs: 0,
        kv_block: 16,
        kv_blocks: 64,
        max_seq: 256,
        max_prefills_per_iter: 2,
        queue_cap: 16,
        prefill_chunk: 0,
        threads,
        kv_dtype: mergequant::engine::KvDtype::F32,
        prefix_cache: true,
        prefix_cache_blocks: 0,
        max_decode_latency: 0,
        speculative: false,
        draft_k: 0,
        draft_layers: 0,
    };
    let router = Router::start(RouterConfig::new(2, cfg), |i| {
        Engine::new(build_model("mergequant")
            .unwrap_or_else(|e| panic!("reloading replica {i}: {e:#}"))
            .0)
    });

    const SESSIONS: usize = 3;
    const TURNS: usize = 3;
    const MAX_NEW: usize = 6;
    let mut prompts: Vec<Vec<u32>> = (0..SESSIONS)
        .map(|s| (0..24)
            .map(|j| 3 + ((s * 31 + j * 7) % 89) as u32)
            .collect())
        .collect();
    let mut drained_replica = None;
    for turn in 0..TURNS {
        for (s, prompt) in prompts.iter_mut().enumerate() {
            if turn > 0 {
                // Follow-up turn: prior prompt + completion + fresh
                // user tokens — the pinned replica replays none of it.
                prompt.extend((0..4).map(|j| {
                    5 + ((s * 13 + turn * 17 + j * 5) % 89) as u32
                }));
            }
            let golden = golden_engine.generate(prompt, MAX_NEW, 256)?;
            let mut params = GenerationParams::greedy(MAX_NEW);
            params.session = Some(format!("chat-{s}"));
            let resp = router
                .generate(prompt.clone(), params)
                .map_err(anyhow::Error::msg)?
                .wait();
            anyhow::ensure!(resp.error.is_none(),
                            "turn failed: {:?}", resp.error);
            anyhow::ensure!(resp.tokens == golden,
                            "routing must never change stream content \
                             (session {s}, turn {turn})");
            prompt.extend(&resp.tokens);
        }
        println!("turn {turn}: {SESSIONS} sessions streamed, all \
                  bitwise ≡ Engine::generate goldens ✓");
        if turn == 0 {
            // Mid-run drain: retire whichever replica session chat-0
            // pinned. The fleet is idle between turns, so one poll
            // tears it down and respawns it (generation + 1); chat-0's
            // stale pin re-routes on its next turn instead of erroring.
            let victim = router
                .session_replica("chat-0")
                .expect("chat-0 must be pinned after its first turn");
            router.drain(victim).map_err(anyhow::Error::msg)?;
            let deadline = std::time::Instant::now()
                + std::time::Duration::from_secs(10);
            while router.poll_drains() > 0 {
                anyhow::ensure!(std::time::Instant::now() < deadline,
                                "drain stuck");
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            println!("drained replica {victim} after turn 0 — in-flight \
                      work finished, respawned clean, router kept \
                      admitting");
            drained_replica = Some(victim);
        }
    }
    let m = router.metrics();
    anyhow::ensure!(m.drains == 1 && m.respawns == 1,
                    "exactly one drain + respawn expected");
    anyhow::ensure!(m.rerouted >= 1,
                    "the drained replica's pins must re-route");
    println!("affinity: {} hits / {} misses; {} session(s) re-routed \
              off drained replica {}",
             m.affinity_hits, m.affinity_misses, m.rerouted,
             drained_replica.unwrap_or_default());
    // Multi-line shutdown report: the router aggregate line (dispatch
    // counts, affinity_hit_rate — CI greps these), the drained
    // replica's final report, then each live replica's report.
    println!("{}\n", router.shutdown());
    Ok(())
}

// ---------------------------------------------------------------------
// Part 2: closed-loop fleet over the v2 streaming TCP protocol
// ---------------------------------------------------------------------

struct RunStats {
    wall_s: f64,
    gen_tokens: usize,
    lat_ms: Vec<f64>,
    ttft_ms: Vec<f64>,
    /// Client-observed TTFT: send → first `token` frame on the wire.
    client_ttft_ms: Vec<f64>,
}

impl RunStats {
    fn new() -> Self {
        RunStats {
            wall_s: 0.0,
            gen_tokens: 0,
            lat_ms: Vec::new(),
            ttft_ms: Vec::new(),
            client_ttft_ms: Vec::new(),
        }
    }
}

fn drive(method: &str, n_requests: usize, n_clients: usize,
         prompt_len: usize, max_new: usize, kernel_threads: usize)
         -> anyhow::Result<RunStats> {
    let bundle = artifacts_dir()
        .join(format!("models/tiny-llama-s/{method}.qmod"));
    let engine = Engine::new(QModel::load(&bundle)?);
    let vocab = engine.config().vocab as u32;
    let server = Arc::new(Server::start(
        engine,
        SchedulerConfig {
            max_batch: 8,
            kv_slabs: 8,
            kv_block: 32,
            kv_blocks: 0,
            max_seq: prompt_len + max_new + 4,
            max_prefills_per_iter: 2,
            queue_cap: 256,
            prefill_chunk: 0,
            threads: kernel_threads,
            kv_dtype: mergequant::engine::KvDtype::F32,
            prefix_cache: false,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    ));
    let gateway = TcpGateway::start(server.clone(), 0)?;
    let addr = gateway.addr;

    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    let per_client = n_requests / n_clients;
    for c in 0..n_clients {
        handles.push(std::thread::spawn(move || -> anyhow::Result<RunStats> {
            let mut rng = Rng::new(100 + c as u64);
            let stream = TcpStream::connect(addr)?;
            let mut reader = BufReader::new(stream.try_clone()?);
            let mut out = stream;
            let mut stats = RunStats::new();
            for _ in 0..per_client {
                // Poisson think time (closed loop, ~20 req/s offered)
                std::thread::sleep(std::time::Duration::from_secs_f64(
                    rng.exp(20.0)));
                let prompt: Vec<String> = (0..prompt_len)
                    .map(|_| (3 + rng.next_u64() % (vocab as u64 - 3))
                        .to_string())
                    .collect();
                // v2 streaming request (greedy params keep the paper's
                // token streams; the protocol is the thing under test).
                let sent = std::time::Instant::now();
                writeln!(out,
                         "{{\"prompt\":[{}],\"params\":{{\"max_new\":{max_new}}}}}",
                         prompt.join(","))?;
                let mut first_token_at: Option<f64> = None;
                loop {
                    let mut line = String::new();
                    if reader.read_line(&mut line)? == 0 {
                        anyhow::bail!("gateway closed mid-stream");
                    }
                    let j = Json::parse(line.trim())
                        .map_err(anyhow::Error::msg)?;
                    match j.get("event").and_then(Json::as_str) {
                        Some("token") => {
                            if first_token_at.is_none() {
                                first_token_at = Some(
                                    sent.elapsed().as_secs_f64() * 1e3);
                            }
                            stats.gen_tokens += 1;
                        }
                        Some("done") => {
                            if let Some(l) =
                                j.get("latency_ms").and_then(Json::as_f64)
                            {
                                stats.lat_ms.push(l);
                            }
                            if let Some(t) =
                                j.get("ttft_ms").and_then(Json::as_f64)
                            {
                                stats.ttft_ms.push(t);
                            }
                            if let Some(t) = first_token_at {
                                stats.client_ttft_ms.push(t);
                            }
                            break;
                        }
                        Some("error") => anyhow::bail!(
                            "request failed: {:?}", j.get("error")),
                        _ => anyhow::bail!("unexpected frame {line:?}"),
                    }
                }
            }
            Ok(stats)
        }));
    }
    let mut agg = RunStats::new();
    for h in handles {
        let s = h.join().expect("client panicked")?;
        agg.gen_tokens += s.gen_tokens;
        agg.lat_ms.extend(s.lat_ms);
        agg.ttft_ms.extend(s.ttft_ms);
        agg.client_ttft_ms.extend(s.client_ttft_ms);
    }
    agg.wall_s = t0.elapsed().as_secs_f64();
    gateway.stop();
    println!("  scheduler: {}", server.shutdown());
    Ok(agg)
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let n_requests = args.get_usize("requests", 24);
    let n_clients = args.get_usize("clients", 4);
    let prompt_len = args.get_usize("prompt-len", 64);
    let max_new = args.get_usize("max-new", 32);
    // Engine intra-op kernel threads (0 = all cores) — DESIGN.md §7.
    let kernel_threads = args.get_usize("threads", 1);

    api_demo(kernel_threads)?;
    preemption_demo(kernel_threads)?;
    speculative_demo(kernel_threads)?;
    router_demo(kernel_threads)?;

    if !artifacts_dir().join("models/tiny-llama-s/mergequant.qmod").exists() {
        eprintln!("(skipping fleet run: run `make artifacts` first)");
        return Ok(());
    }
    println!("== serve_e2e fleet: {n_requests} requests, {n_clients} \
              clients, prompt {prompt_len}, decode {max_new}, v2 \
              streaming ==");
    let mut throughput = std::collections::HashMap::new();
    for method in ["fp16", "mergequant"] {
        println!("[{method}]");
        let s = drive(method, n_requests, n_clients, prompt_len, max_new,
                      kernel_threads)?;
        let lat = summarize(&s.lat_ms);
        let ttft = summarize(&s.ttft_ms);
        let cttft = summarize(&s.client_ttft_ms);
        let tput = s.gen_tokens as f64 / s.wall_s;
        println!("  wall {:.2}s  throughput {:.1} gen tok/s", s.wall_s, tput);
        println!("  latency p50 {:.1}ms p99 {:.1}ms; ttft p50 {:.1}ms \
                  (client-observed first frame p50 {:.1}ms)",
                 lat.p50, lat.p99, ttft.p50, cttft.p50);
        throughput.insert(method, tput);
    }
    if let (Some(fp), Some(mq)) =
        (throughput.get("fp16"), throughput.get("mergequant"))
    {
        println!("serving throughput speedup (mergequant vs fp16): {:.2}x",
                 mq / fp);
    }
    Ok(())
}
