//! End-to-end serving driver (the DESIGN.md validation run): start the
//! coordinator on a quantized bundle, attach the TCP gateway, fire a
//! closed-loop client fleet with Poisson think times at it, and report
//! latency/throughput — then do the same for the FP16 bundle and print
//! the serving-level speedup.
//!
//! ```sh
//! cargo run --release --example serve_e2e [-- --requests 32 --clients 4 --threads 4]
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use mergequant::cli::Args;
use mergequant::coordinator::server::TcpGateway;
use mergequant::coordinator::{SchedulerConfig, Server};
use mergequant::engine::{Engine, QModel};
use mergequant::util::json::Json;
use mergequant::util::rng::Rng;
use mergequant::util::stats::summarize;
use mergequant::artifacts_dir;

struct RunStats {
    wall_s: f64,
    gen_tokens: usize,
    lat_ms: Vec<f64>,
    ttft_ms: Vec<f64>,
}

fn drive(method: &str, n_requests: usize, n_clients: usize,
         prompt_len: usize, max_new: usize, kernel_threads: usize)
         -> anyhow::Result<RunStats> {
    let bundle = artifacts_dir()
        .join(format!("models/tiny-llama-s/{method}.qmod"));
    let engine = Engine::new(QModel::load(&bundle)?);
    let vocab = engine.config().vocab as u32;
    let server = Arc::new(Server::start(
        engine,
        SchedulerConfig {
            max_batch: 8,
            kv_slabs: 8,
            max_seq: prompt_len + max_new + 4,
            max_prefills_per_iter: 2,
            queue_cap: 256,
            prefill_chunk: 0,
            threads: kernel_threads,
            kv_dtype: mergequant::engine::KvDtype::F32,
        },
    ));
    let gateway = TcpGateway::start(server.clone(), 0)?;
    let addr = gateway.addr;

    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    let per_client = n_requests / n_clients;
    for c in 0..n_clients {
        handles.push(std::thread::spawn(move || -> anyhow::Result<RunStats> {
            let mut rng = Rng::new(100 + c as u64);
            let stream = TcpStream::connect(addr)?;
            let mut reader = BufReader::new(stream.try_clone()?);
            let mut out = stream;
            let mut stats = RunStats {
                wall_s: 0.0, gen_tokens: 0,
                lat_ms: Vec::new(), ttft_ms: Vec::new(),
            };
            for _ in 0..per_client {
                // Poisson think time (closed loop, ~20 req/s offered)
                std::thread::sleep(std::time::Duration::from_secs_f64(
                    rng.exp(20.0)));
                let prompt: Vec<String> = (0..prompt_len)
                    .map(|_| (3 + rng.next_u64() % (vocab as u64 - 3))
                        .to_string())
                    .collect();
                writeln!(out, "{{\"prompt\":[{}],\"max_new\":{max_new}}}",
                         prompt.join(","))?;
                let mut line = String::new();
                reader.read_line(&mut line)?;
                let j = Json::parse(line.trim()).map_err(anyhow::Error::msg)?;
                stats.gen_tokens += j.get("tokens")
                    .and_then(Json::as_arr).map_or(0, |a| a.len());
                if let Some(l) = j.get("latency_ms").and_then(Json::as_f64) {
                    stats.lat_ms.push(l);
                }
                if let Some(t) = j.get("ttft_ms").and_then(Json::as_f64) {
                    stats.ttft_ms.push(t);
                }
            }
            Ok(stats)
        }));
    }
    let mut agg = RunStats {
        wall_s: 0.0, gen_tokens: 0, lat_ms: Vec::new(), ttft_ms: Vec::new(),
    };
    for h in handles {
        let s = h.join().expect("client panicked")?;
        agg.gen_tokens += s.gen_tokens;
        agg.lat_ms.extend(s.lat_ms);
        agg.ttft_ms.extend(s.ttft_ms);
    }
    agg.wall_s = t0.elapsed().as_secs_f64();
    gateway.stop();
    let report = match Arc::try_unwrap(server) {
        Ok(srv) => srv.shutdown(),
        Err(_) => String::new(),
    };
    println!("  scheduler: {report}");
    Ok(agg)
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let n_requests = args.get_usize("requests", 24);
    let n_clients = args.get_usize("clients", 4);
    let prompt_len = args.get_usize("prompt-len", 64);
    let max_new = args.get_usize("max-new", 32);
    // Engine intra-op kernel threads (0 = all cores) — DESIGN.md §7.
    let kernel_threads = args.get_usize("threads", 1);

    if !artifacts_dir().join("models/tiny-llama-s/mergequant.qmod").exists() {
        eprintln!("run `make artifacts` first");
        return Ok(());
    }
    println!("== serve_e2e: {n_requests} requests, {n_clients} clients, \
              prompt {prompt_len}, decode {max_new} ==");
    let mut throughput = std::collections::HashMap::new();
    for method in ["fp16", "mergequant"] {
        println!("[{method}]");
        let s = drive(method, n_requests, n_clients, prompt_len, max_new,
                      kernel_threads)?;
        let lat = summarize(&s.lat_ms);
        let ttft = summarize(&s.ttft_ms);
        let tput = s.gen_tokens as f64 / s.wall_s;
        println!("  wall {:.2}s  throughput {:.1} gen tok/s", s.wall_s, tput);
        println!("  latency p50 {:.1}ms p99 {:.1}ms; ttft p50 {:.1}ms",
                 lat.p50, lat.p99, ttft.p50);
        throughput.insert(method, tput);
    }
    if let (Some(fp), Some(mq)) =
        (throughput.get("fp16"), throughput.get("mergequant"))
    {
        println!("serving throughput speedup (mergequant vs fp16): {:.2}x",
                 mq / fp);
    }
    Ok(())
}
