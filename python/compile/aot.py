"""AOT build: train models, quantize with every method, export artifacts.

``make artifacts`` runs this once; the Rust binary is self-contained
afterwards (Python never on the request path). Outputs under artifacts/:

  corpora/            token streams + meta (synth-wiki, synth-c4)
  tasks/              five zero-shot choice tasks (JSON)
  models/<m>/<meth>.qmod     quantized bundles (DESIGN.md §4 experiments)
  models/<m>/train_log.json  training loss curve (e2e validation run)
  hlo/                prefill/decode HLO text (fp32 + mergequant, Pallas)
  goldens/            logits + greedy-decode goldens for Rust parity tests
  reports/            figs 5-7 channel/clip data, Table 8 runtimes
  manifest.json       index of everything above

Every stage is idempotent: existing outputs are skipped unless --force.
HLO is emitted as *text* via the stablehlo→XlaComputation bridge —
serialized protos from jax≥0.5 are rejected by xla_extension 0.5.1
(see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import model as M
from . import qmod as QM
from . import train as T
from .quant import calibration as C
from .quant import pipeline as P
from .quant.qforward import quant_decode_step, quant_forward

ART = Path(__file__).resolve().parents[2] / "artifacts"

# Mirror the paper's Table 1 row structure per model (DESIGN.md §5).
TABLE1_PLAN = {
    "tiny-llama-s": P.TABLE1_METHODS,
    "tiny-llama-m": P.TABLE1_METHODS,
    "tiny-llama-l": ["fp16", "smoothquant", "qllm", "quarot_nh",
                     "mergequant_nh", "quarot", "spinquant", "mergequant"],
    "tiny-llama3": ["fp16", "quarot", "spinquant", "mergequant"],
}

# Step budgets sized for the single-core build box: enough to learn the
# bigram structure (loss well below unigram entropy) without dominating
# `make artifacts` wall-clock.
TRAIN_STEPS = {"tiny-llama-s": 500, "tiny-llama-m": 250,
               "tiny-llama-l": 120, "tiny-llama3": 200}
TRAIN_BATCH = {"tiny-llama-s": 32, "tiny-llama-m": 32,
               "tiny-llama-l": 16, "tiny-llama3": 24}


def calib_batches(n_batches: int = 12, batch: int = 4, seq: int = 128,
                  seed: int = 3) -> list[np.ndarray]:
    """Mixed synth-wiki + synth-c4 calibration set (paper App. B)."""
    wiki = D.generate_corpus(D.SYNTH_WIKI, 200_000)
    c4 = D.generate_corpus(D.SYNTH_C4, 100_000)
    mix = np.concatenate([wiki, c4])
    it = D.batch_iterator(mix, batch, seq, seed=seed)
    return [next(it)[0] for _ in range(n_batches)]


def stage_data(force: bool = False) -> None:
    if not force and (ART / "corpora" / "corpora.json").exists():
        return
    D.export_corpora(ART / "corpora", train_tokens=120_000, val_tokens=24_000)
    D.export_tasks(ART / "tasks", n_items=200)
    print("[data] corpora + tasks exported")


def stage_models(force: bool = False) -> dict:
    params_by_model = {}
    for name, cfg in M.MODEL_ZOO.items():
        params, log = T.train_or_load(cfg, ART / "models" / name,
                                      steps=TRAIN_STEPS[name],
                                      batch=TRAIN_BATCH[name])
        params_by_model[name] = params
        print(f"[models] {name}: {cfg.param_count()/1e6:.2f}M params, "
              f"final loss {log[-1]['loss']:.4f}")
    return params_by_model


def _method_plan() -> dict[str, list[str]]:
    plan: dict[str, set[str]] = {n: set() for n in M.MODEL_ZOO}
    for model, methods in TABLE1_PLAN.items():
        plan[model].update(methods)
    plan["tiny-llama3"].update(P.TABLE4_METHODS)
    plan["tiny-llama-s"].update(P.TABLE5_METHODS)
    # Table 7 covers every Llama in the paper; we run its rows on the
    # smallest and the hardest-to-quantize models (build-box budget).
    for model in ("tiny-llama-s", "tiny-llama3"):
        plan[model].update(P.TABLE7_METHODS)
    plan["tiny-llama-s"].update(P.FIG1_METHODS)
    return {k: sorted(v) for k, v in plan.items()}


def stage_bundles(params_by_model: dict, force: bool = False) -> dict:
    batches = calib_batches()
    runtimes: dict[str, dict] = {}
    plan = _method_plan()
    for model, methods in plan.items():
        cfg = M.MODEL_ZOO[model]
        params = params_by_model[model]
        calib = None
        runtimes[model] = {}
        for meth in methods:
            out = ART / "models" / model / f"{meth}.qmod"
            if out.exists() and not force:
                continue
            t0 = time.time()
            if calib is None:
                calib = C.calibrate(cfg, params, batches)
            qm = P.build_method(meth, cfg, params, batches, calib=calib)
            QM.save_qmod(out, qm)
            dt = time.time() - t0
            runtimes[model][meth] = dt
            print(f"[bundles] {model}/{meth}: {dt:.1f}s "
                  f"({out.stat().st_size/1e6:.1f} MB)")
    return runtimes


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants: the model weights are baked into the graph as
    # constants; the default printer elides them to "{...}" and the rust
    # loader would silently get all-zero weights.
    return comp.as_hlo_text(print_large_constants=True)


def stage_hlo(params_by_model: dict, force: bool = False,
              batch: int = 1, seq: int = 128, max_seq: int = 192) -> None:
    """Export prefill + decode HLO for the PJRT runtime (tiny-llama-s)."""
    hlo_dir = ART / "hlo"
    hlo_dir.mkdir(parents=True, exist_ok=True)
    name = "tiny-llama-s"
    cfg = M.MODEL_ZOO[name]
    params = jax.tree.map(jnp.asarray, params_by_model[name])
    qm = QM.load_qmod(ART / "models" / name / "mergequant.qmod")

    tok_spec = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    tok1_spec = jax.ShapeDtypeStruct((batch,), jnp.int32)
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
    kshape = (cfg.n_layers, batch, max_seq, cfg.n_heads, cfg.head_dim)
    kv_spec = jax.ShapeDtypeStruct(kshape, jnp.float32)

    jobs = {
        "tiny-llama-s.prefill.fp32":
            (lambda t: (M.forward(cfg, params, t),), [tok_spec]),
        "tiny-llama-s.decode.fp32":
            (lambda t, p, k, v: M.decode_step(cfg, params, t, p, k, v),
             [tok1_spec, pos_spec, kv_spec, kv_spec]),
        "tiny-llama-s.prefill.mergequant":
            (lambda t: (quant_forward(cfg, qm, t, use_pallas=True),),
             [tok_spec]),
        "tiny-llama-s.decode.mergequant":
            (lambda t, p, k, v: quant_decode_step(cfg, qm, t, p, k, v,
                                                  use_pallas=True),
             [tok1_spec, pos_spec, kv_spec, kv_spec]),
    }
    meta = {}
    for jname, (fn, specs) in jobs.items():
        out = hlo_dir / f"{jname}.hlo.txt"
        meta[jname] = {"batch": batch, "seq": seq, "max_seq": max_seq}
        if out.exists() and not force:
            continue
        t0 = time.time()
        text = to_hlo_text(jax.jit(fn).lower(*specs))
        out.write_text(text)
        print(f"[hlo] {jname}: {len(text)/1e6:.2f}M chars, "
              f"{time.time()-t0:.1f}s")
    (hlo_dir / "hlo.json").write_text(json.dumps(meta))


def stage_goldens(params_by_model: dict, force: bool = False) -> None:
    """Logit + greedy-decode goldens binding JAX semantics to the engine."""
    gold = ART / "goldens"
    gold.mkdir(parents=True, exist_ok=True)
    if (gold / "goldens.json").exists() and not force:
        return
    name = "tiny-llama-s"
    cfg = M.MODEL_ZOO[name]
    params = jax.tree.map(jnp.asarray, params_by_model[name])
    rng = np.random.default_rng(42)
    toks = rng.integers(3, cfg.vocab, size=(2, 64)).astype(np.int32)
    (gold / "tokens.i32").write_bytes(toks.astype("<i4").tobytes())

    index = {"tokens_shape": list(toks.shape), "logits": {}}
    fp_logits = np.asarray(M.forward(cfg, params, jnp.asarray(toks)),
                           np.float32)
    (gold / "fp32.logits.f32").write_bytes(fp_logits.astype("<f4").tobytes())
    index["logits"]["fp32"] = {"file": "fp32.logits.f32",
                               "shape": list(fp_logits.shape)}
    for meth in ("mergequant", "mergequant_nh", "rtn", "smoothquant",
                 "quarot"):
        path = ART / "models" / name / f"{meth}.qmod"
        if not path.exists():
            continue
        qm = QM.load_qmod(path)
        lg = np.asarray(quant_forward(cfg, qm, jnp.asarray(toks)), np.float32)
        fn = f"{meth}.logits.f32"
        (gold / fn).write_bytes(lg.astype("<f4").tobytes())
        index["logits"][meth] = {"file": fn, "shape": list(lg.shape)}

    # Greedy continuation golden (fp32 path), 24 tokens from a fixed prompt.
    prompt = toks[0, :16].tolist()
    seqtoks = list(prompt)
    for _ in range(24):
        lg = np.asarray(M.forward(cfg, params,
                                  jnp.asarray(np.asarray(seqtoks)[None])))
        seqtoks.append(int(np.argmax(lg[0, -1])))
    index["greedy"] = {"prompt": prompt, "completion": seqtoks[len(prompt):]}
    (gold / "goldens.json").write_text(json.dumps(index))
    print("[goldens] written")


def stage_reports(params_by_model: dict, bundle_runtimes: dict,
                  force: bool = False) -> None:
    rep = ART / "reports"
    rep.mkdir(parents=True, exist_ok=True)
    batches = calib_batches(n_batches=6)
    # Figs 5/6: channel absmax of qkv/up/gate inputs for two models.
    if force or not (rep / "fig5_6_channels.json").exists():
        out = {}
        for name in ("tiny-llama-s", "tiny-llama-m"):
            cfg = M.MODEL_ZOO[name]
            calib = C.calibrate(cfg, params_by_model[name], batches)
            out[name] = C.channel_absmax_report(calib)
        (rep / "fig5_6_channels.json").write_text(json.dumps(out))
        print("[reports] fig5_6_channels")
    # Fig 7 + Table 8: clip ratios and stage runtimes from a pipeline run.
    if force or not (rep / "fig7_clips.json").exists():
        clips = {}
        table8 = {}
        for name, cfg in M.MODEL_ZOO.items():
            report: dict = {}
            P.mergequant(cfg, params_by_model[name], batches,
                         collect_report=report)
            clips[name] = {
                "o_clip": [l["o_clip"] for l in report["layers"]],
                "down_clip": [l["down_clip"] for l in report["layers"]],
                "qkv_channel_clips": [l["attn"]["clip_ratios"]
                                      for l in report["layers"]],
            }
            table8[name] = {
                "calib_seconds": report["calib_seconds"],
                "quantize_seconds": report["quantize_seconds"],
                "bundle_seconds": bundle_runtimes.get(name, {}),
            }
        (rep / "fig7_clips.json").write_text(json.dumps(clips))
        (rep / "table8_runtime.json").write_text(json.dumps(table8))
        print("[reports] fig7_clips + table8_runtime")


def write_manifest() -> None:
    files = sorted(str(p.relative_to(ART)) for p in ART.rglob("*")
                   if p.is_file() and p.name != "manifest.json")
    (ART / "manifest.json").write_text(json.dumps({
        "models": {n: dataclasses.asdict(c) for n, c in M.MODEL_ZOO.items()},
        "method_plan": _method_plan(),
        "table1_plan": TABLE1_PLAN,
        "files": files,
    }, default=list))
    print(f"[manifest] {len(files)} files")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--stage", default="all",
                    choices=["all", "data", "models", "bundles", "hlo",
                             "goldens", "reports"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=None, help="unused; Makefile compat")
    args = ap.parse_args()

    t0 = time.time()
    stage_data(args.force)
    if args.stage == "data":
        return
    params = stage_models(args.force)
    runtimes = {}
    if args.stage in ("all", "bundles", "hlo", "goldens", "reports"):
        if args.stage in ("all", "bundles"):
            runtimes = stage_bundles(params, args.force)
        if args.stage in ("all", "hlo"):
            stage_hlo(params, args.force)
        if args.stage in ("all", "goldens"):
            stage_goldens(params, args.force)
        if args.stage in ("all", "reports"):
            stage_reports(params, runtimes, args.force)
    write_manifest()
    print(f"[aot] done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
