"""Synthetic corpora and evaluation tasks.

The paper calibrates/evaluates on WikiText-2 and C4 and five zero-shot
choice tasks. Neither the datasets nor the Llama checkpoints are available
in this offline image, so we build the closest synthetic equivalents
(DESIGN.md §2):

* ``synth-wiki`` / ``synth-c4`` — topic-mixture bigram languages over a
  512-word vocabulary with Zipfian unigram priors. The two corpora share
  the vocabulary but differ in topic priors and sampling temperature, so a
  model trained on the mix shows a (small) domain gap between them, just
  as Llama does between WikiText-2 and C4.
* five choice tasks (``synth-piqa`` .. ``synth-winogrande``) — real
  continuations from the generator vs. corrupted distractors, scored with
  length-normalised log-likelihood exactly like lm-eval-harness scores
  PIQA/ARC/HellaSwag/WinoGrande.

Everything is deterministic given the seed so that artifacts are
reproducible and the Rust side can re-derive nothing.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import numpy as np

VOCAB_SIZE = 512
BOS = 0
EOS = 1
PAD = 2
N_SPECIAL = 3
N_TOPICS = 8


@dataclasses.dataclass
class CorpusSpec:
    """Sampling parameters for one synthetic corpus."""

    name: str
    seed: int
    temperature: float
    topic_concentration: float  # Dirichlet concentration over topics
    doc_len: tuple[int, int]  # min/max document length (tokens)


SYNTH_WIKI = CorpusSpec("synth-wiki", seed=7, temperature=1.0,
                        topic_concentration=0.4, doc_len=(64, 256))
SYNTH_C4 = CorpusSpec("synth-c4", seed=11, temperature=1.15,
                      topic_concentration=1.2, doc_len=(48, 192))


class BigramWorld:
    """Shared latent structure: per-topic bigram transition tables.

    One fixed ``BigramWorld`` underlies both corpora; the corpora differ in
    *how* they sample from it (topic prior, temperature). A trained model
    therefore learns genuine transferable structure.
    """

    def __init__(self, seed: int = 1234, vocab: int = VOCAB_SIZE,
                 n_topics: int = N_TOPICS):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        self.n_topics = n_topics
        # Zipfian unigram prior over the non-special vocabulary.
        ranks = np.arange(1, vocab - N_SPECIAL + 1)
        zipf = 1.0 / ranks**1.05
        self.unigram = zipf / zipf.sum()
        # Per-topic sparse bigram logits: each token prefers a topic-specific
        # set of ~24 successors, blended with the unigram prior.
        self.next_tokens = rng.integers(
            N_SPECIAL, vocab, size=(n_topics, vocab, 24))
        self.next_logits = rng.gumbel(size=(n_topics, vocab, 24)) * 1.2 + 2.0

    def sample_doc(self, rng: np.random.Generator, topic_probs: np.ndarray,
                   length: int, temperature: float) -> np.ndarray:
        topic = int(rng.choice(self.n_topics, p=topic_probs))
        out = np.empty(length + 2, dtype=np.int32)
        out[0] = BOS
        tok = int(N_SPECIAL + rng.choice(len(self.unigram), p=self.unigram))
        out[1] = tok
        nxt = self.next_tokens[topic]
        lgt = self.next_logits[topic] / temperature
        for i in range(2, length + 1):
            if rng.random() < 0.08:  # unigram resets keep entropy realistic
                tok = int(N_SPECIAL +
                          rng.choice(len(self.unigram), p=self.unigram))
            else:
                p = np.exp(lgt[tok] - lgt[tok].max())
                p /= p.sum()
                tok = int(nxt[tok][rng.choice(24, p=p)])
            out[i] = tok
        out[length + 1] = EOS
        return out


_WORLD: BigramWorld | None = None


def world() -> BigramWorld:
    global _WORLD
    if _WORLD is None:
        _WORLD = BigramWorld()
    return _WORLD


def sample_topic_probs(rng: np.random.Generator, spec: CorpusSpec) -> np.ndarray:
    return rng.dirichlet(np.full(N_TOPICS, spec.topic_concentration))


def generate_corpus(spec: CorpusSpec, n_tokens: int) -> np.ndarray:
    """Concatenated token stream of exactly ``n_tokens`` tokens.

    Sequential bigram sampling is a Python loop, so streams are cached on
    disk (deterministic given the spec) and longer cached streams serve
    shorter requests by prefix.
    """
    cache_dir = Path(__file__).resolve().parents[2] / "artifacts" / "corpora_cache"
    cache_dir.mkdir(parents=True, exist_ok=True)
    for existing in sorted(cache_dir.glob(f"{spec.name}-*.npy")):
        try:
            cached_n = int(existing.stem.split("-")[-1])
        except ValueError:
            continue
        if cached_n >= n_tokens:
            return np.load(existing)[:n_tokens]
    rng = np.random.default_rng(spec.seed)
    w = world()
    chunks: list[np.ndarray] = []
    total = 0
    while total < n_tokens:
        length = int(rng.integers(*spec.doc_len))
        doc = w.sample_doc(rng, sample_topic_probs(rng, spec), length,
                           spec.temperature)
        chunks.append(doc)
        total += len(doc)
    out = np.concatenate(chunks)[:n_tokens]
    np.save(cache_dir / f"{spec.name}-{n_tokens}.npy", out)
    return out


def batch_iterator(tokens: np.ndarray, batch: int, seq: int, seed: int = 0):
    """Yield (inputs, targets) int32 batches forever (training iterator)."""
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq - 1
    while True:
        starts = rng.integers(0, n, size=batch)
        x = np.stack([tokens[s:s + seq] for s in starts])
        y = np.stack([tokens[s + 1:s + seq + 1] for s in starts])
        yield x.astype(np.int32), y.astype(np.int32)


# ---------------------------------------------------------------------------
# Zero-shot choice tasks
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ChoiceItem:
    prefix: list[int]
    choices: list[list[int]]  # token sequences
    answer: int


def _corrupt_swap(rng, seq):
    seq = list(seq)
    if len(seq) >= 4:
        i, j = rng.choice(len(seq), size=2, replace=False)
        seq[i], seq[j] = seq[j], seq[i]
    return seq


def _corrupt_random(rng, seq):
    return [int(N_SPECIAL + rng.integers(0, VOCAB_SIZE - N_SPECIAL))
            for _ in seq]


def _corrupt_topic(rng, w: BigramWorld, seq, temperature=1.0):
    """Plausible same-length continuation from a *different* topic."""
    topic = int(rng.integers(0, w.n_topics))
    tok = int(seq[0])
    out = [tok]
    for _ in range(len(seq) - 1):
        lgt = w.next_logits[topic][tok] / temperature
        p = np.exp(lgt - lgt.max())
        p /= p.sum()
        tok = int(w.next_tokens[topic][tok][rng.choice(24, p=p)])
        out.append(tok)
    return out


def make_task(name: str, n_items: int, seed: int) -> list[ChoiceItem]:
    """Build one synthetic choice task.

    ``piqa``: 2-choice, swap corruption (subtle) — mirrors physical
    plausibility being a small perturbation.
    ``arc-e``: 4-choice, random-token distractors (easy).
    ``arc-c``: 4-choice, other-topic plausible distractors (hard).
    ``hellaswag``: 4-choice, longer continuations, other-topic distractors.
    ``winogrande``: 2-choice, single-token difference.
    """
    rng = np.random.default_rng(seed)
    w = world()
    spec = SYNTH_WIKI
    items: list[ChoiceItem] = []
    for _ in range(n_items):
        probs = sample_topic_probs(rng, spec)
        cont_len = 12 if name != "hellaswag" else 24
        doc = w.sample_doc(rng, probs, 32 + cont_len, spec.temperature)
        prefix = doc[: 32].tolist()
        true_cont = doc[32: 32 + cont_len].tolist()
        if name == "piqa":
            distractors = [_corrupt_swap(rng, true_cont)]
        elif name == "arc-e":
            distractors = [_corrupt_random(rng, true_cont) for _ in range(3)]
        elif name in ("arc-c", "hellaswag"):
            distractors = [_corrupt_topic(rng, w, true_cont) for _ in range(3)]
        elif name == "winogrande":
            d = list(true_cont)
            pos = int(rng.integers(0, len(d)))
            d[pos] = int(N_SPECIAL + rng.integers(0, VOCAB_SIZE - N_SPECIAL))
            distractors = [d]
        else:
            raise ValueError(name)
        answer = int(rng.integers(0, 1 + len(distractors)))
        choices = list(distractors)
        choices.insert(answer, true_cont)
        items.append(ChoiceItem(prefix, choices, answer))
    return items


TASK_NAMES = ["piqa", "arc-e", "arc-c", "hellaswag", "winogrande"]


def export_tasks(out_dir: Path, n_items: int = 200, seed: int = 99) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    for i, name in enumerate(TASK_NAMES):
        items = make_task(name, n_items, seed + i)
        payload = [dataclasses.asdict(it) for it in items]
        (out_dir / f"{name}.json").write_text(json.dumps(payload))


def export_corpora(out_dir: Path, train_tokens: int, val_tokens: int) -> dict:
    """Write train/val token streams for both corpora as little-endian i32."""
    out_dir.mkdir(parents=True, exist_ok=True)
    meta = {}
    for spec in (SYNTH_WIKI, SYNTH_C4):
        full = generate_corpus(spec, train_tokens + val_tokens)
        train, val = full[:train_tokens], full[train_tokens:]
        (out_dir / f"{spec.name}.train.i32").write_bytes(
            train.astype("<i4").tobytes())
        (out_dir / f"{spec.name}.val.i32").write_bytes(
            val.astype("<i4").tobytes())
        meta[spec.name] = {"train_tokens": int(train_tokens),
                           "val_tokens": int(val_tokens)}
    (out_dir / "corpora.json").write_text(json.dumps(meta))
    return meta
