"""`.qmod` — the quantized-model bundle format (Python writer + reader).

Layout (little-endian):

    magic   b"QMOD1\\n"
    u32     meta_len
    bytes   meta (JSON, UTF-8)
    bytes   tensor blobs, each 64-byte aligned, raw little-endian

The JSON meta carries the model config, the method name, the full
structural schema (norm specs, linear modes, scalars) and a tensor table
``[{name, dtype, shape, offset, nbytes}]``. The Rust loader
(rust/src/engine/qmod.rs) mirrors this exactly; tests on both sides parse
the same fixture.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import numpy as np

from .model import ModelConfig
from .quant.quantizer import QWeight

MAGIC = b"QMOD1\n"
ALIGN = 64

_DTYPES = {"f32": ("<f4", 4), "i8": ("<i1", 1), "i32": ("<i4", 4),
           "i16": ("<i2", 2)}


class _Writer:
    def __init__(self):
        self.tensors: list[dict] = []
        self.blobs: list[bytes] = []
        self.offset = 0

    def add(self, name: str, arr: np.ndarray) -> str:
        if arr.dtype == np.float32:
            dt = "f32"
        elif arr.dtype == np.int8:
            dt = "i8"
        elif arr.dtype == np.int16:
            dt = "i16"
        elif arr.dtype == np.int32:
            dt = "i32"
        else:
            raise TypeError(f"{name}: {arr.dtype}")
        raw = np.ascontiguousarray(arr).astype(_DTYPES[dt][0]).tobytes()
        pad = (-self.offset) % ALIGN
        if pad:
            self.blobs.append(b"\0" * pad)
            self.offset += pad
        self.tensors.append({"name": name, "dtype": dt,
                             "shape": list(arr.shape),
                             "offset": self.offset, "nbytes": len(raw)})
        self.blobs.append(raw)
        self.offset += len(raw)
        return name


def _qweight_meta(w: _Writer, prefix: str, qw: QWeight) -> dict:
    meta = {"bits": qw.bits, "group": qw.group, "sym": qw.zero is None,
            "wq": w.add(f"{prefix}.wq", qw.wq.astype(np.int8)),
            "scale": w.add(f"{prefix}.scale", qw.scale.astype(np.float32))}
    if qw.zero is not None:
        meta["zero"] = w.add(f"{prefix}.zero", qw.zero.astype(np.int16))
    return meta


def _linear_meta(w: _Writer, prefix: str, spec: dict) -> dict:
    mode = spec["mode"]
    meta: dict = {"mode": mode}
    if mode == "fp":
        meta["w"] = w.add(f"{prefix}.w", np.asarray(spec["w"], np.float32))
        return meta
    meta["qw"] = _qweight_meta(w, prefix, spec["qw"])
    if mode == "tensor_static":
        meta["a_scale"] = float(spec["a_scale"])
        meta["a_qmax"] = int(spec["a_qmax"])
    elif mode == "channel_static":
        # Format 3: a_scale is a tensor *name* (per-input-channel static
        # scales), plus the optional reconstruction gather indices.
        meta["a_qmax"] = int(spec["a_qmax"])
        meta["a_scale"] = w.add(f"{prefix}.a_scale",
                                np.asarray(spec["a_scale"], np.float32))
        if spec.get("recon_idx") is not None:
            meta["recon_idx"] = w.add(
                f"{prefix}.recon_idx",
                np.asarray(spec["recon_idx"], np.int32))
    elif mode == "dynamic":
        meta["a_qmax"] = int(spec["a_qmax"])
        meta["a_clip"] = float(spec.get("a_clip", 1.0))
        meta["hadamard"] = bool(spec.get("hadamard", False))
    return meta


def _norm_meta(w: _Writer, prefix: str, spec: dict) -> dict:
    meta: dict = {"g": w.add(f"{prefix}.g", np.asarray(spec["g"], np.float32))}
    q = spec.get("quant")
    if q is not None:
        meta["quant"] = {"qmax": int(q["qmax"])}
        if q.get("recon_idx") is not None:
            meta["quant"]["recon_idx"] = w.add(
                f"{prefix}.recon_idx",
                np.asarray(q["recon_idx"], np.int32))
    return meta


def save_qmod(path: Path, qm: dict) -> None:
    cfg: ModelConfig = qm["config"]
    w = _Writer()
    kv_scales = qm.get("kv")
    layers_meta = []
    for i, layer in enumerate(qm["layers"]):
        p = f"layers.{i}"
        lm = {
            "attn_norm": _norm_meta(w, f"{p}.attn_norm", layer["attn_norm"]),
            "q": _linear_meta(w, f"{p}.q", layer["q"]),
            "k": _linear_meta(w, f"{p}.k", layer["k"]),
            "v": _linear_meta(w, f"{p}.v", layer["v"]),
            "o": _linear_meta(w, f"{p}.o", layer["o"]),
            "ffn_norm": _norm_meta(w, f"{p}.ffn_norm", layer["ffn_norm"]),
            "gate": _linear_meta(w, f"{p}.gate", layer["gate"]),
            "up": _linear_meta(w, f"{p}.up", layer["up"]),
            "down": _linear_meta(w, f"{p}.down", layer["down"]),
        }
        # Format 2: calibrated static INT8 KV-cache scales per layer.
        if kv_scales is not None:
            kv = kv_scales[i]
            lm["kv"] = {
                name: w.add(f"{p}.kv.{name}",
                            np.asarray(kv[name], np.float32))
                for name in ("k_scale", "v_scale", "qk_scale")
            }
        layers_meta.append(lm)
    # Format history: 1 = base schema, 2 = + per-layer KV scales,
    # 3 = + channel_static linears (per-channel static activation quant).
    has_chan_static = any(
        layer[k]["mode"] == "channel_static"
        for layer in qm["layers"]
        for k in ("q", "k", "v", "o", "gate", "up", "down"))
    meta = {
        "format": (3 if has_chan_static
                   else 2 if kv_scales is not None else 1),
        "method": qm["method"],
        "config": {**dataclasses.asdict(cfg),
                   "outlier_channels": list(cfg.outlier_channels)},
        "embed": w.add("embed", np.asarray(qm["embed"], np.float32)),
        "outlier_gain": w.add("outlier_gain",
                              np.asarray(qm["outlier_gain"], np.float32)),
        "final_norm": w.add("final_norm",
                            np.asarray(qm["final_norm"], np.float32)),
        "lm_head": w.add("lm_head", np.asarray(qm["lm_head"], np.float32)),
        "layers": layers_meta,
        "tensors": w.tensors,
    }
    meta_bytes = json.dumps(meta).encode()
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(len(meta_bytes).to_bytes(4, "little"))
        f.write(meta_bytes)
        base = f.tell()
        pad = (-base) % ALIGN
        f.write(b"\0" * pad)
        for blob in w.blobs:
            f.write(blob)


def load_qmod(path: Path) -> dict:
    """Read a .qmod back into the qforward QuantModel structure (tests)."""
    raw = Path(path).read_bytes()
    assert raw[:len(MAGIC)] == MAGIC, "bad magic"
    mlen = int.from_bytes(raw[len(MAGIC):len(MAGIC) + 4], "little")
    meta = json.loads(raw[len(MAGIC) + 4:len(MAGIC) + 4 + mlen])
    base = len(MAGIC) + 4 + mlen
    base += (-base) % ALIGN
    table = {t["name"]: t for t in meta["tensors"]}

    def tensor(name: str) -> np.ndarray:
        t = table[name]
        dt, _ = _DTYPES[t["dtype"]]
        start = base + t["offset"]
        arr = np.frombuffer(raw, dtype=dt, count=int(np.prod(t["shape"])) if t["shape"] else 1,
                            offset=start)
        return arr.reshape(t["shape"]).copy()

    def qweight(m: dict) -> QWeight:
        return QWeight(wq=tensor(m["wq"]).astype(np.int8),
                       scale=tensor(m["scale"]),
                       zero=tensor(m["zero"]) if "zero" in m else None,
                       group=m["group"], bits=m["bits"])

    def linear(m: dict) -> dict:
        if m["mode"] == "fp":
            return {"mode": "fp", "w": tensor(m["w"])}
        spec = {"mode": m["mode"], "qw": qweight(m["qw"])}
        if m["mode"] == "tensor_static":
            spec["a_scale"] = m["a_scale"]
            spec["a_qmax"] = m["a_qmax"]
        elif m["mode"] == "channel_static":
            spec["a_scale"] = tensor(m["a_scale"])
            spec["a_qmax"] = m["a_qmax"]
            spec["recon_idx"] = (tensor(m["recon_idx"])
                                 if "recon_idx" in m else None)
        elif m["mode"] == "dynamic":
            spec["a_qmax"] = m["a_qmax"]
            spec["a_clip"] = m["a_clip"]
            spec["hadamard"] = m["hadamard"]
        return spec

    def norm(m: dict) -> dict:
        spec = {"g": tensor(m["g"]), "quant": None}
        if "quant" in m:
            q = {"qmax": m["quant"]["qmax"], "recon_idx": None}
            if "recon_idx" in m["quant"]:
                q["recon_idx"] = tensor(m["quant"]["recon_idx"])
            spec["quant"] = q
        return spec

    ccfg = dict(meta["config"])
    ccfg["outlier_channels"] = tuple(ccfg["outlier_channels"])
    cfg = ModelConfig(**ccfg)
    kv = None
    n_kv = sum("kv" in lm for lm in meta["layers"])
    if n_kv:
        if n_kv != len(meta["layers"]):
            raise ValueError(
                f"kv scales on {n_kv} of {len(meta['layers'])} layers "
                "(must be all or none)")
        kv = [
            {name: tensor(lm["kv"][name])
             for name in ("k_scale", "v_scale", "qk_scale")}
            for lm in meta["layers"]
        ]
    return {
        "config": cfg,
        "method": meta["method"],
        "kv": kv,
        "embed": tensor("embed"),
        "outlier_gain": tensor("outlier_gain"),
        "final_norm": tensor("final_norm"),
        "lm_head": tensor("lm_head"),
        "layers": [
            {
                "attn_norm": norm(lm["attn_norm"]),
                "q": linear(lm["q"]), "k": linear(lm["k"]),
                "v": linear(lm["v"]), "o": linear(lm["o"]),
                "ffn_norm": norm(lm["ffn_norm"]),
                "gate": linear(lm["gate"]), "up": linear(lm["up"]),
                "down": linear(lm["down"]),
            }
            for lm in meta["layers"]
        ],
    }
