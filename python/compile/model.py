"""L2: tiny Llama-architecture model in JAX.

Implements the FP32 reference forward (training + evaluation), the
single-token decode step with an explicit KV cache (exported to HLO for the
Rust PJRT runtime), and evaluation helpers. The *quantized* forward lives
in ``python/compile/qforward.py``.

Architecture = Llama: RMSNorm, RoPE, MHA, SwiGLU FFN, untied LM head.
One deliberate addition: a fixed per-channel ``outlier gain`` applied to
the embedding output. Real Llama activations carry structured outliers in
a handful of channels (paper Fig. 5/6); a ~1M-parameter model trained for a
few hundred steps does not develop them reliably, so we bake the mechanism
into the architecture — the model trains *with* the gain, and every
residual-stream activation inherits the structured-outlier pattern the
paper's method exists to handle. See DESIGN.md §2.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int = 512
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 512
    n_layers: int = 4
    max_seq: int = 512
    rope_theta: float = 10000.0
    # channels that get an architectural gain (induced structured outliers)
    outlier_channels: tuple[int, ...] = (7, 33, 71)
    outlier_gain: float = 12.0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        per_layer = 4 * d * d + 3 * d * f + 2 * d
        return v * d + L * per_layer + d + d * v


# The four models of DESIGN.md §5 (stand-ins for Llama-2 7B/13B/70B, Llama-3-8B).
MODEL_ZOO: dict[str, ModelConfig] = {
    "tiny-llama-s": ModelConfig("tiny-llama-s", d_model=128, n_heads=4,
                                d_ff=512, n_layers=4, vocab=512),
    "tiny-llama-m": ModelConfig("tiny-llama-m", d_model=192, n_heads=6,
                                d_ff=512, n_layers=6, vocab=512,
                                outlier_channels=(7, 33, 71, 150)),
    "tiny-llama-l": ModelConfig("tiny-llama-l", d_model=256, n_heads=8,
                                d_ff=1024, n_layers=8, vocab=512,
                                outlier_channels=(7, 33, 71, 150, 201)),
    "tiny-llama3": ModelConfig("tiny-llama3", d_model=192, n_heads=6,
                               d_ff=512, n_layers=6, vocab=1024,
                               outlier_channels=(7, 33, 71, 150),
                               outlier_gain=18.0),
}


def outlier_gain_vector(cfg: ModelConfig) -> np.ndarray:
    g = np.ones(cfg.d_model, dtype=np.float32)
    for c in cfg.outlier_channels:
        g[c % cfg.d_model] = cfg.outlier_gain
    return g


def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    """Scaled-normal init, Llama-style."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    keys = iter(jax.random.split(key, 4 + 8 * cfg.n_layers))

    def dense(k, n, m):
        return jax.random.normal(k, (n, m), jnp.float32) / np.sqrt(n)

    params: Params = {
        "embed": jax.random.normal(next(keys), (v, d), jnp.float32) * 0.02,
        "outlier_gain": jnp.asarray(outlier_gain_vector(cfg)),
        "final_norm": jnp.ones((d,), jnp.float32),
        "lm_head": dense(next(keys), d, v),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        params["layers"].append({
            "attn_norm": jnp.ones((d,), jnp.float32),
            "wq": dense(next(keys), d, d),
            "wk": dense(next(keys), d, d),
            "wv": dense(next(keys), d, d),
            "wo": dense(next(keys), d, d),
            "ffn_norm": jnp.ones((d,), jnp.float32),
            "w_gate": dense(next(keys), d, f),
            "w_up": dense(next(keys), d, f),
            "w_down": dense(next(keys), f, d),
        })
    return params


def rmsnorm(x: jax.Array, g: jax.Array, eps: float = 1e-5) -> jax.Array:
    rms = jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return x / rms * g


def rope_angles(cfg: ModelConfig, positions: jax.Array):
    """cos/sin tables for given positions: (T, head_dim/2)."""
    hd = cfg.head_dim
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, T, H, head_dim); cos/sin: (T, head_dim/2)."""
    x1, x2 = x[..., 0::2], x[..., 1::2]
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    out = jnp.stack([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.reshape(x.shape)


def attention(q, k, v, causal_from: int = 0):
    """q: (B,Tq,H,hd), k/v: (B,Tk,H,hd). Causal mask offset by causal_from
    (absolute position of q[0]) so decode steps attend to the full cache."""
    _, Tq, _, hd = q.shape
    Tk = k.shape[1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    qpos = jnp.arange(Tq)[:, None] + causal_from
    kpos = jnp.arange(Tk)[None, :]
    mask = kpos <= qpos
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def block_forward(cfg: ModelConfig, layer: Params, x: jax.Array,
                  cos: jax.Array, sin: jax.Array) -> jax.Array:
    B, T, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    h = rmsnorm(x, layer["attn_norm"])
    q = (h @ layer["wq"]).reshape(B, T, H, hd)
    k = (h @ layer["wk"]).reshape(B, T, H, hd)
    v = (h @ layer["wv"]).reshape(B, T, H, hd)
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    attn = attention(q, k, v).reshape(B, T, d)
    x = x + attn @ layer["wo"]
    h = rmsnorm(x, layer["ffn_norm"])
    ff = jax.nn.silu(h @ layer["w_gate"]) * (h @ layer["w_up"])
    return x + ff @ layer["w_down"]


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array) -> jax.Array:
    """FP32 reference forward: tokens (B,T) int32 -> logits (B,T,V)."""
    x = params["embed"][tokens] * params["outlier_gain"]
    cos, sin = rope_angles(cfg, jnp.arange(tokens.shape[1]))
    for layer in params["layers"]:
        x = block_forward(cfg, layer, x, cos, sin)
    x = rmsnorm(x, params["final_norm"])
    return x @ params["lm_head"]


def loss_fn(cfg: ModelConfig, params: Params, tokens: jax.Array,
            targets: jax.Array) -> jax.Array:
    logits = forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Decode step with explicit KV cache (exported to HLO for the PJRT runtime)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    shape = (cfg.n_layers, batch, max_seq, cfg.n_heads, cfg.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def decode_step(cfg: ModelConfig, params: Params, token: jax.Array,
                pos: jax.Array, kcache: jax.Array, vcache: jax.Array):
    """One decode step.

    token: (B,) int32; pos: scalar int32 (current position);
    kcache/vcache: (L,B,maxT,H,hd). Returns (logits (B,V), kcache, vcache).
    """
    B = token.shape[0]
    H, hd, d = cfg.n_heads, cfg.head_dim, cfg.d_model
    maxT = kcache.shape[2]
    x = params["embed"][token][:, None, :] * params["outlier_gain"]  # (B,1,d)
    cos, sin = rope_angles(cfg, pos[None])
    visible = (jnp.arange(maxT) <= pos)[None, None, None, :]  # (1,1,1,maxT)
    new_k, new_v = kcache, vcache
    for li, layer in enumerate(params["layers"]):
        h = rmsnorm(x, layer["attn_norm"])
        q = (h @ layer["wq"]).reshape(B, 1, H, hd)
        k = (h @ layer["wk"]).reshape(B, 1, H, hd)
        v = (h @ layer["wv"]).reshape(B, 1, H, hd)
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
        kc = jax.lax.dynamic_update_slice(new_k[li], k, (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(new_v[li], v, (0, pos, 0, 0))
        new_k = new_k.at[li].set(kc)
        new_v = new_v.at[li].set(vc)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kc) / np.sqrt(hd)
        scores = jnp.where(visible, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhqk,bkhd->bqhd", probs, vc).reshape(B, 1, d)
        x = x + attn @ layer["wo"]
        hn = rmsnorm(x, layer["ffn_norm"])
        ff = jax.nn.silu(hn @ layer["w_gate"]) * (hn @ layer["w_up"])
        x = x + ff @ layer["w_down"]
    x = rmsnorm(x, params["final_norm"])
    logits = (x @ params["lm_head"])[:, 0, :]
    return logits, new_k, new_v


# ---------------------------------------------------------------------------
# Evaluation helpers (used by pytest, the pipeline and artifact goldens)
# ---------------------------------------------------------------------------

def perplexity(cfg: ModelConfig, params: Params, tokens: np.ndarray,
               seq: int = 256, forward_fn=None) -> float:
    """Non-overlapping windows, mean NLL exponentiated."""
    fwd = forward_fn or jax.jit(lambda t: forward(cfg, params, t))
    n = (len(tokens) - 1) // seq
    total, count = 0.0, 0
    for i in range(n):
        x = jnp.asarray(tokens[i * seq:(i + 1) * seq][None])
        y = tokens[i * seq + 1:(i + 1) * seq + 1]
        logits = jnp.asarray(fwd(x))[0]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -np.asarray(logp)[np.arange(seq), y]
        total += float(nll.sum())
        count += seq
    return float(np.exp(total / max(count, 1)))


def choice_accuracy(cfg: ModelConfig, params: Params, items: list,
                    forward_fn=None) -> float:
    """Length-normalised log-likelihood scoring (lm-eval-harness rule).

    ``items``: list of dicts {prefix, choices, answer} (see data.make_task).
    """
    fwd = forward_fn or jax.jit(lambda t: forward(cfg, params, t))
    correct = 0
    for it in items:
        prefix, choices = it["prefix"], it["choices"]
        scores = []
        for ch in choices:
            toks = np.asarray(prefix + ch, dtype=np.int32)
            logits = jnp.asarray(fwd(jnp.asarray(toks[None])))[0]
            logp = jax.nn.log_softmax(logits, axis=-1)
            span = np.arange(len(prefix) - 1, len(toks) - 1)
            tgt = toks[span + 1]
            ll = float(np.asarray(logp)[span, tgt].sum())
            scores.append(ll / max(len(ch), 1))
        if int(np.argmax(scores)) == it["answer"]:
            correct += 1
    return correct / max(len(items), 1)
