"""L1 Pallas kernel: fused RMSNorm + static per-channel quantize (Eq. 4).

After quantization migration the RMSNorm multiplier holds γ_k / s_k, so
normalising and quantizing is a *single* VMEM-resident pass: load an
(bm, d) activation tile, compute the row RMS, multiply by the merged
vector, round, clamp — the integer activations stream straight into the
QSM matmul kernel. This is the CUDA "fused norm+quant" kernel rethought
for TPU (DESIGN.md §8): d stays whole in the lane dimension (d ≤ 1024
everywhere in the zoo, far under VMEM), the grid tiles only rows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 64


def _rmsnorm_quant_kernel(x_ref, g_ref, o_ref, *, qmax, eps):
    x = x_ref[...]
    rms = jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    v = x / rms * g_ref[...][None, :]
    o_ref[...] = jnp.clip(jnp.sign(v) * jnp.floor(jnp.abs(v) + 0.5),
                          -qmax, qmax)


@functools.partial(jax.jit, static_argnames=("qmax", "eps", "bm"))
def rmsnorm_quant(x: jax.Array, g_merged: jax.Array, qmax: int = 7,
                  eps: float = 1e-5, bm: int = DEFAULT_BM) -> jax.Array:
    """x: (m, d) f32; g_merged: (d,) = γ/s. Returns int-valued f32 (m, d)."""
    m, d = x.shape
    bm_ = min(bm, m)
    kern = functools.partial(_rmsnorm_quant_kernel, qmax=qmax, eps=eps)
    return pl.pallas_call(
        kern,
        grid=(pl.cdiv(m, bm_),),
        in_specs=[
            pl.BlockSpec((bm_, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm_, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=True,
    )(x, g_merged)


def _rmsnorm_quant_recon_kernel(x_ref, g_ref, idx_ref, o_ref, *, qmax, eps):
    x = x_ref[...]
    rms = jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    v = x / rms * g_ref[...][None, :]
    q = jnp.clip(jnp.sign(v) * jnp.floor(jnp.abs(v) + 0.5), -qmax, qmax)
    o_ref[...] = jnp.take(q, idx_ref[...], axis=-1)


@functools.partial(jax.jit, static_argnames=("qmax", "eps", "bm"))
def rmsnorm_quant_recon(x: jax.Array, g_merged: jax.Array, recon_idx: jax.Array,
                        qmax: int = 7, eps: float = 1e-5,
                        bm: int = DEFAULT_BM) -> jax.Array:
    """Fused norm + quantize + dimension reconstruction (paper App. C.1).

    ``recon_idx`` (d,) gathers the kept channels and duplicates the split
    "strong parameter" channels — the only runtime cost MergeQuant adds,
    and it fuses into the same VMEM pass as the norm.
    """
    m, d = x.shape
    bm_ = min(bm, m)
    kern = functools.partial(_rmsnorm_quant_recon_kernel, qmax=qmax, eps=eps)
    return pl.pallas_call(
        kern,
        grid=(pl.cdiv(m, bm_),),
        in_specs=[
            pl.BlockSpec((bm_, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm_, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=True,
    )(x, g_merged, recon_idx)
