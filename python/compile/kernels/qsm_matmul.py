"""L1 Pallas kernel: QSM-aligned static-quant matmul (paper Eq. 5).

The paper's point is that after Quantization Step Migration the per-channel
static path looks *exactly* like a per-tensor int GEMM: integer activations
(already scaled by the merged RMSNorm multiplier), integer weights (with
the per-channel activation scale folded along the input dimension), and a
single per-output-column rescale in the epilogue. On CUDA that aligns with
CUTLASS INT4 GEMM; on TPU we express it as an MXU-shaped Pallas kernel:

  grid (M/bm, J/bj); each program holds an (bm, n) activation tile and an
  (n, bj) weight tile in VMEM, accumulates on the MXU, and applies the
  per-column ``out_scale`` epilogue before writing back — one HBM round
  trip for the output, zero explicit Quant/DeQuant passes.

``interpret=True`` everywhere: the CPU PJRT client cannot run Mosaic
custom-calls (see /opt/xla-example/README.md). Numerics are validated
against ``ref.py`` by pytest; TPU perf is estimated structurally
(DESIGN.md §8, EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 64
DEFAULT_BJ = 128


def _qsm_kernel(xq_ref, wq_ref, scale_ref, o_ref):
    acc = jnp.dot(xq_ref[...], wq_ref[...],
                  preferred_element_type=jnp.float32)
    o_ref[...] = acc * scale_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bj"))
def qsm_matmul(xq: jax.Array, wq: jax.Array, out_scale: jax.Array,
               bm: int = DEFAULT_BM, bj: int = DEFAULT_BJ) -> jax.Array:
    """xq: (m, n) int-valued f32; wq: (n, j) int-valued f32; out_scale: (j,).

    Returns (m, j) f32 = (xq @ wq) * out_scale.
    """
    m, n = xq.shape
    n2, j = wq.shape
    assert n == n2, (xq.shape, wq.shape)
    bm_ = min(bm, m)
    bj_ = min(bj, j)
    grid = (pl.cdiv(m, bm_), pl.cdiv(j, bj_))
    return pl.pallas_call(
        _qsm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, n), lambda i, k: (i, 0)),
            pl.BlockSpec((n, bj_), lambda i, k: (0, k)),
            pl.BlockSpec((bj_,), lambda i, k: (k,)),
        ],
        out_specs=pl.BlockSpec((bm_, bj_), lambda i, k: (i, k)),
        out_shape=jax.ShapeDtypeStruct((m, j), jnp.float32),
        interpret=True,
    )(xq, wq, out_scale)


def _qsm_asym_kernel(xq_ref, wq_ref, zero_ref, scale_ref, o_ref):
    xq = xq_ref[...]
    acc = jnp.dot(xq, wq_ref[...], preferred_element_type=jnp.float32)
    rowsum = jnp.sum(xq, axis=-1, keepdims=True)
    o_ref[...] = (acc - rowsum * zero_ref[...][None, :]) * scale_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bj"))
def qsm_matmul_asym(xq: jax.Array, wq: jax.Array, zero: jax.Array,
                    out_scale: jax.Array, bm: int = DEFAULT_BM,
                    bj: int = DEFAULT_BJ) -> jax.Array:
    """Asymmetric-weight variant (Table 5): Y = ((xq@wq) - rowsum·z) · s_j.

    The zero-point correction costs one extra row reduction that stays in
    VMEM — still no per-channel work in the accumulator.
    """
    m, n = xq.shape
    _, j = wq.shape
    bm_ = min(bm, m)
    bj_ = min(bj, j)
    grid = (pl.cdiv(m, bm_), pl.cdiv(j, bj_))
    return pl.pallas_call(
        _qsm_asym_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, n), lambda i, k: (i, 0)),
            pl.BlockSpec((n, bj_), lambda i, k: (0, k)),
            pl.BlockSpec((bj_,), lambda i, k: (k,)),
            pl.BlockSpec((bj_,), lambda i, k: (k,)),
        ],
        out_specs=pl.BlockSpec((bm_, bj_), lambda i, k: (i, k)),
        out_shape=jax.ShapeDtypeStruct((m, j), jnp.float32),
        interpret=True,
    )(xq, wq, zero, out_scale)


def _dyn_kernel(x_ref, wq_ref, wscale_ref, o_ref, *, qmax, clip):
    x = x_ref[...]
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    s = jnp.maximum(absmax * clip / qmax, 1e-8)
    q = x / s
    xq = jnp.clip(jnp.sign(q) * jnp.floor(jnp.abs(q) + 0.5), -qmax, qmax)
    acc = jnp.dot(xq, wq_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = acc * s * wscale_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("qmax", "clip", "bm", "bj"))
def dyn_quant_matmul(x: jax.Array, wq: jax.Array, w_scale: jax.Array,
                     qmax: int = 7, clip: float = 1.0,
                     bm: int = DEFAULT_BM, bj: int = DEFAULT_BJ) -> jax.Array:
    """Per-token *dynamic* baseline kernel (the cost MergeQuant removes).

    Fusing quantize+GEMM into one kernel is the best case for dynamic
    quantization; the paper's Table 6 overhead is the *unfused* PyTorch
    reality, which our Rust substrate reproduces. Keeping this kernel
    fused makes our accuracy comparisons conservative.
    """
    m, n = x.shape
    _, j = wq.shape
    bm_ = min(bm, m)
    bj_ = min(bj, j)
    grid = (pl.cdiv(m, bm_), pl.cdiv(j, bj_))
    kern = functools.partial(_dyn_kernel, qmax=qmax, clip=clip)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, n), lambda i, k: (i, 0)),
            pl.BlockSpec((n, bj_), lambda i, k: (0, k)),
            pl.BlockSpec((bj_,), lambda i, k: (k,)),
        ],
        out_specs=pl.BlockSpec((bm_, bj_), lambda i, k: (i, k)),
        out_shape=jax.ShapeDtypeStruct((m, j), jnp.float32),
        interpret=True,
    )(x, wq, w_scale)


def vmem_footprint_bytes(m: int, n: int, j: int, bm: int = DEFAULT_BM,
                         bj: int = DEFAULT_BJ, act_bytes: int = 1,
                         w_bytes: int = 1) -> dict:
    """Structural VMEM estimate for one grid step (DESIGN.md §8).

    act tile (bm, n) + weight tile (n, bj) + f32 accumulator (bm, bj)
    + scale vector. Used by EXPERIMENTS.md §Perf to check the schedule
    fits comfortably under the ~16 MiB TPU VMEM budget.
    """
    bm = min(bm, m)
    bj = min(bj, j)
    act = bm * n * act_bytes
    wgt = n * bj * w_bytes
    acc = bm * bj * 4
    scale = bj * 4
    total = act + wgt + acc + scale
    return {"act": act, "weight": wgt, "acc": acc, "scale": scale,
            "total": total, "fits_16MiB": total < 16 * 2**20}
