"""Pure-jnp oracles for the Pallas kernels.

These are the *correctness* definitions; the Pallas kernels in
``qsm_matmul.py`` / ``rmsnorm_quant.py`` must match them bit-for-bit
(same rounding semantics) under pytest sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def round_half_away(x: jax.Array) -> jax.Array:
    """Round-half-away-from-zero — the ⌈·⌋ of the paper's Eq. (1).

    Matches ``f32::round`` in Rust so the native engine and the JAX
    pipeline agree exactly (jnp.round is banker's rounding, which does not).
    """
    return jnp.sign(x) * jnp.floor(jnp.abs(x) + 0.5)


def quantize_sym(x: jax.Array, scale: jax.Array, qmax: int) -> jax.Array:
    """Symmetric quantization: round(x/scale) clamped to [-qmax, qmax].

    Returns integer *values* in float32 (the TPU MXU consumes bf16/int8
    operands; carrying int values in f32 keeps interpret-mode exact).
    """
    return jnp.clip(round_half_away(x / scale), -qmax, qmax)


def rmsnorm_quant_ref(x: jax.Array, g_merged: jax.Array, qmax: int,
                      eps: float = 1e-5) -> jax.Array:
    """Paper Eq. (4): RMSNorm whose multiplier already holds γ/s.

    x: (..., d); g_merged: (d,) = gamma / s_channel.
    Output: integer-valued f32 in [-qmax, qmax].
    """
    rms = jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return jnp.clip(round_half_away(x / rms * g_merged), -qmax, qmax)


def qsm_matmul_ref(xq: jax.Array, wq: jax.Array, out_scale: jax.Array) -> jax.Array:
    """Paper Eq. (5): integer GEMM with per-output-column rescale epilogue.

    xq: (m, n) integer-valued f32 (quantized activations, scale already
    migrated into the norm multiplier); wq: (n, j) integer-valued f32
    (weights with s_k folded in, then per-column quantized);
    out_scale: (j,) the per-column dequant factor s_j^{s_X·W}.
    """
    acc = jnp.dot(xq, wq, preferred_element_type=jnp.float32)
    return acc * out_scale


def qsm_matmul_asym_ref(xq: jax.Array, wq: jax.Array, zero: jax.Array,
                        out_scale: jax.Array) -> jax.Array:
    """Asymmetric-weight variant (Table 5): W_int = round(W/s)+z.

    Y = s_j * (Σ_k xq_k wq_kj  −  z_j Σ_k xq_k).
    """
    acc = jnp.dot(xq, wq, preferred_element_type=jnp.float32)
    rowsum = jnp.sum(xq, axis=-1, keepdims=True)
    return (acc - rowsum * zero[None, :]) * out_scale


def dyn_quant_matmul_ref(x: jax.Array, wq: jax.Array, w_scale: jax.Array,
                         qmax: int, clip: float = 1.0) -> jax.Array:
    """Per-token dynamic baseline (out/down layers + RTN/QuaRot baselines).

    x: (m, n) f32; per-row scale s_t = clip·absmax/qmax computed *online* —
    this is the explicit Quant/DeQuant step MergeQuant eliminates.
    """
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    s = jnp.maximum(absmax * clip / qmax, 1e-8)
    xq = jnp.clip(round_half_away(x / s), -qmax, qmax)
    acc = jnp.dot(xq, wq, preferred_element_type=jnp.float32)
    return acc * s * w_scale


def hadamard_block64_ref(x: jax.Array) -> jax.Array:
    """Normalised block-diagonal Walsh–Hadamard transform, block size 64.

    Any d divisible by 64 is supported; this is the online rotation used by
    the '+hadamard' variants (DESIGN.md §2 hardware note).
    """
    d = x.shape[-1]
    assert d % 64 == 0, d
    shape = x.shape
    x = x.reshape(-1, d // 64, 64)
    h = 1
    while h < 64:
        x = x.reshape(x.shape[0], x.shape[1], -1, 2, h)
        a = x[..., 0, :]
        b = x[..., 1, :]
        x = jnp.concatenate([a + b, a - b], axis=-1)
        h *= 2
    x = x.reshape(shape)
    return x / jnp.sqrt(64.0)
