"""Build-time training of the tiny model zoo (DESIGN.md §5).

AdamW on the synth-wiki + synth-c4 mix. This is also the end-to-end
training validation run required by the brief: the loss curve of every
model is written to ``artifacts/models/<name>/train_log.json`` and
summarized in EXPERIMENTS.md.
"""

from __future__ import annotations

import json
import pickle
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import model as M


def adamw_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adamw_update(params, grads, state, lr, b1=0.9, b2=0.95, eps=1e-8,
                 wd=0.01):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)

    def upd(p, m_, v_):
        return p - lr * (m_ * mhat_scale / (jnp.sqrt(v_ * vhat_scale) + eps)
                         + wd * p)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def train_model(cfg: M.ModelConfig, steps: int = 400, batch: int = 32,
                seq: int = 128, lr: float = 3e-3, seed: int = 0,
                log_every: int = 25):
    """Returns (params, train_log)."""
    wiki = D.generate_corpus(D.SYNTH_WIKI, 400_000)
    c4 = D.generate_corpus(D.SYNTH_C4, 200_000)
    mix = np.concatenate([wiki, c4])
    it = D.batch_iterator(mix, batch, seq, seed=seed)

    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    # Freeze the architectural outlier-gain (it is part of the architecture,
    # not a learned parameter — see model.py docstring).
    gain = params.pop("outlier_gain")

    def loss(p, x, y):
        return M.loss_fn(cfg, {**p, "outlier_gain": gain}, x, y)

    grad_fn = jax.jit(jax.value_and_grad(loss))
    opt = adamw_init(params)
    log = []
    t0 = time.time()
    for step in range(steps):
        x, y = next(it)
        lval, grads = grad_fn(params, jnp.asarray(x), jnp.asarray(y))
        warm = min(1.0, (step + 1) / 40)
        decay = 0.5 * (1 + np.cos(np.pi * step / steps))
        params, opt = adamw_update(params, grads, opt, lr * warm * (0.1 + 0.9 * decay))
        if step % log_every == 0 or step == steps - 1:
            log.append({"step": step, "loss": float(lval),
                        "elapsed_s": time.time() - t0})
            print(f"[{cfg.name}] step {step:4d} loss {float(lval):.4f}")
    params["outlier_gain"] = gain
    return params, log


def train_or_load(cfg: M.ModelConfig, cache_dir: Path, steps: int = 400,
                  **kw):
    """Train once; cache the pickled params + log under cache_dir."""
    cache_dir.mkdir(parents=True, exist_ok=True)
    pkl = cache_dir / f"{cfg.name}.params.pkl"
    logf = cache_dir / f"{cfg.name}.train_log.json"
    if pkl.exists():
        with open(pkl, "rb") as f:
            return pickle.load(f), json.loads(logf.read_text())
    params, log = train_model(cfg, steps=steps, **kw)
    params = jax.tree.map(lambda a: np.asarray(a, np.float32), params)
    with open(pkl, "wb") as f:
        pickle.dump(params, f)
    logf.write_text(json.dumps(log))
    return params, log
