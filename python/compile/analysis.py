"""Build-time analysis tooling (EXPERIMENTS.md §Perf L1/L2 evidence).

* ``hlo_stats`` — op-census of an exported HLO text module: counts by
  opcode, dot/fusion counts, constant payload bytes, parameter count.
  Used to audit the lowered graphs (no duplicated norm subgraphs, KV
  updated via dynamic-update-slice, integer dots present in the
  quantized module).
* ``vmem_report`` — structural VMEM footprint of the L1 Pallas schedule
  across the model zoo + paper-scale shapes (DESIGN.md §8).
* ``alpha_sweep`` — dimension-reconstruction behaviour vs the Eq. (6)
  threshold hyperparameter α: how many strong channels, split elements,
  and what residual scale non-uniformity remains. This is the design-
  choice ablation DESIGN.md calls out (α=5 for Llama-2-likes, α=2 for
  the Llama-3-like).

CLI: ``python -m compile.analysis [hlo|vmem|alpha|all]`` →
``artifacts/reports/analysis_*.json`` + stdout summary.
"""

from __future__ import annotations

import json
import re
import sys
from collections import Counter
from pathlib import Path

import numpy as np

ART = Path(__file__).resolve().parents[2] / "artifacts"


def hlo_stats(text: str) -> dict:
    """Opcode census of an HLO text module."""
    ops = Counter()
    const_bytes = 0
    params = 0
    for line in text.splitlines():
        m = re.search(r"=\s*[a-z0-9\[\],{}:\s]*?([a-z][a-z0-9-]*)\(", line)
        if not m:
            continue
        op = m.group(1)
        ops[op] += 1
        if op == "parameter":
            params += 1
        if "constant(" in line:
            # rough payload size: count numeric literals on the line
            const_bytes += 4 * max(line.count(",") + 1, 1)
    return {
        "total_ops": sum(ops.values()),
        "by_opcode": dict(ops.most_common()),
        "dots": ops.get("dot", 0),
        "dynamic_update_slices": ops.get("dynamic-update-slice", 0),
        "parameters": params,
        "approx_constant_bytes": const_bytes,
    }


def run_hlo() -> dict:
    out = {}
    hlo_dir = ART / "hlo"
    for path in sorted(hlo_dir.glob("*.hlo.txt")):
        stats = hlo_stats(path.read_text())
        out[path.stem] = stats
        print(f"[hlo] {path.stem}: {stats['total_ops']} ops, "
              f"{stats['dots']} dots, "
              f"{stats['dynamic_update_slices']} dyn-update-slice, "
              f"{stats['parameters']} params")
    (ART / "reports" / "analysis_hlo.json").write_text(json.dumps(out))
    return out


def run_vmem() -> dict:
    from .kernels.qsm_matmul import vmem_footprint_bytes
    from .model import MODEL_ZOO
    shapes = []
    for cfg in MODEL_ZOO.values():
        shapes.append((cfg.name + ".qkv", 2048, cfg.d_model, 3 * cfg.d_model))
        shapes.append((cfg.name + ".ffn", 2048, cfg.d_model, cfg.d_ff))
    # paper-scale shapes (Llama-2-7B)
    shapes.append(("llama2-7b.qkv", 2048, 4096, 3 * 4096))
    shapes.append(("llama2-7b.ffn", 2048, 4096, 11008))
    out = {}
    for name, m, n, j in shapes:
        fp = vmem_footprint_bytes(m, n, j)
        out[name] = fp
        print(f"[vmem] {name}: {fp['total']/2**20:.2f} MiB "
              f"(fits16MiB={fp['fits_16MiB']})")
    (ART / "reports" / "analysis_vmem.json").write_text(json.dumps(out))
    return out


def run_alpha() -> dict:
    """Sweep the Eq. (6) α on real calibrated scales from the zoo."""
    import pickle

    from .aot import calib_batches
    from .model import MODEL_ZOO
    from .quant import calibration as C
    from .quant.reconstruct import reconstruct

    batches = calib_batches(n_batches=4)
    out = {}
    for name, cfg in MODEL_ZOO.items():
        pkl = ART / "models" / name / f"{name}.params.pkl"
        if not pkl.exists():
            continue
        with open(pkl, "rb") as f:
            params = pickle.load(f)
        calib = C.calibrate(cfg, params, batches)
        stats = calib.layers[0].attn_norm_out
        s = np.maximum(stats.absmax, 1e-6) / 7.0
        rows = {}
        for alpha in (1.0, 2.0, 3.0, 5.0, 8.0):
            r = reconstruct(s, stats.sqsum, alpha=alpha)
            kept = r.fold_scale
            rows[str(alpha)] = {
                "n_strong": int(len(r.strong)),
                "n_split_extra": int(r.n_split_extra),
                "threshold": float(r.threshold),
                "scale_cv_before": float(np.std(s) / np.mean(s)),
                "scale_cv_after": float(np.std(kept) / np.mean(kept)),
            }
            print(f"[alpha] {name} α={alpha}: strong={rows[str(alpha)]['n_strong']} "
                  f"extra={rows[str(alpha)]['n_split_extra']} "
                  f"cv {rows[str(alpha)]['scale_cv_before']:.2f}→"
                  f"{rows[str(alpha)]['scale_cv_after']:.2f}")
        out[name] = rows
    (ART / "reports" / "analysis_alpha.json").write_text(json.dumps(out))
    return out


def main() -> None:
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    (ART / "reports").mkdir(parents=True, exist_ok=True)
    if which in ("hlo", "all"):
        run_hlo()
    if which in ("vmem", "all"):
        run_vmem()
    if which in ("alpha", "all"):
        run_alpha()


if __name__ == "__main__":
    main()
