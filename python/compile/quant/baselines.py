"""Baseline quantization methods (paper §5 "Baselines").

Every method returns a QuantModel (see qforward.py). All are W4A4 by
default and share the weight quantizers (GPTQ / RTN) and the engine's
four linear modes. The *-lite* suffixed methods are faithful-at-our-scale
reductions of the originals (DESIGN.md §2):

* ``rtn``           — per-token dynamic activations, RTN weights.
* ``smoothquant``   — per-channel smoothing folded into norms/weights,
                      then **per-tensor static** activations (the paper's
                      only static baseline, Table 1).
* ``omniquant``-lite— grid-searched equivalent smoothing (the learnable
                      transform) + weight clip search, per-token dynamic.
* ``qllm``-lite     — outlier channel rebalancing (channel disassembly's
                      equalising effect folded diagonally), dynamic.
* ``quarot``        — residual-stream randomized Hadamard rotation, GPTQ,
                      per-token dynamic; ``±`` online block-Hadamard on
                      the down-projection input.
* ``spinquant``     — same, but the rotation is *selected* (proxy for
                      learned): best of K candidates on calibration loss.
* ``quarot_static`` — QuaRot rotation + per-tensor static activations
                      (Table 4 row 1; also Fig. 1 "per-tensor + rotation").
"""

from __future__ import annotations

import numpy as np

from .. import model as M
from . import calibration as C
from . import hadamard as H
from .gptq import gptq_quantize
from .quantizer import qmax_for_bits, quantize_weight
from .qforward import QuantModel


def _np_params(params) -> dict:
    """Copy params to mutable numpy."""
    return {
        "embed": np.asarray(params["embed"], np.float32).copy(),
        "outlier_gain": np.asarray(params["outlier_gain"], np.float32).copy(),
        "final_norm": np.asarray(params["final_norm"], np.float32).copy(),
        "lm_head": np.asarray(params["lm_head"], np.float32).copy(),
        "layers": [
            {k: np.asarray(v, np.float32).copy() for k, v in l.items()}
            for l in params["layers"]
        ],
    }


def fold_norms(params: dict) -> dict:
    """Fold norm γ into the following linears and the outlier gain into the
    embedding, leaving every norm all-ones (rotation precondition)."""
    p = _np_params(params)
    p["embed"] = p["embed"] * p["outlier_gain"][None, :]
    p["outlier_gain"] = np.ones_like(p["outlier_gain"])
    for l in p["layers"]:
        g = l["attn_norm"]
        for w in ("wq", "wk", "wv"):
            l[w] = g[:, None] * l[w]
        l["attn_norm"] = np.ones_like(g)
        g = l["ffn_norm"]
        for w in ("w_gate", "w_up"):
            l[w] = g[:, None] * l[w]
        l["ffn_norm"] = np.ones_like(g)
    g = p["final_norm"]
    p["lm_head"] = g[:, None] * p["lm_head"]
    p["final_norm"] = np.ones_like(g)
    return p


_CTX_MEMO: dict = {}


def _gptq_ctx(x_samples: np.ndarray):
    """Memoize Hessian factorizations across the q/k/v (gate/up) fan-outs
    that share one calibration input array."""
    from .gptq import GptqContext
    key = (id(x_samples), x_samples.shape)
    if key not in _CTX_MEMO:
        if len(_CTX_MEMO) > 32:
            _CTX_MEMO.clear()
        _CTX_MEMO[key] = GptqContext(x_samples)
    return _CTX_MEMO[key]


def _quantize_w(w: np.ndarray, x_samples: np.ndarray | None, *, w_bits: int,
                use_gptq: bool, sym: bool = True, group: int = 0):
    if use_gptq and x_samples is not None:
        return gptq_quantize(w, x_samples, bits=w_bits, sym=sym, group=group,
                             ctx=_gptq_ctx(x_samples))
    return quantize_weight(w, bits=w_bits, sym=sym, group=group)


def _dyn_spec(w, x_samples, *, w_bits, a_bits, use_gptq, hadamard=False,
              a_clip=1.0, sym=True, group=0):
    if hadamard:
        w = H.fold_online_hadamard_into_weight(w)
        if x_samples is not None:
            x_samples = H.fwht_block64(x_samples)
    return {
        "mode": "dynamic",
        "qw": _quantize_w(w, x_samples, w_bits=w_bits, use_gptq=use_gptq,
                          sym=sym, group=group),
        "a_qmax": qmax_for_bits(a_bits),
        "a_clip": float(a_clip),
        "hadamard": bool(hadamard),
    }


def _tensor_static_spec(w, x_samples, a_absmax, *, w_bits, a_bits, use_gptq):
    return {
        "mode": "tensor_static",
        "qw": _quantize_w(w, x_samples, w_bits=w_bits, use_gptq=use_gptq),
        "a_scale": float(max(a_absmax, 1e-8) / qmax_for_bits(a_bits)),
        "a_qmax": qmax_for_bits(a_bits),
    }


def _assemble(cfg, p, layer_specs, method) -> QuantModel:
    return {
        "config": cfg,
        "method": method,
        "embed": p["embed"],
        "outlier_gain": p["outlier_gain"],
        "final_norm": p["final_norm"],
        "lm_head": p["lm_head"],
        "layers": layer_specs,
    }


def _build_token_or_tensor(cfg: M.ModelConfig, p: dict, calib: C.Calibration,
                           *, method: str, activation: str, w_bits: int,
                           a_bits: int, use_gptq: bool,
                           online_hadamard: bool) -> QuantModel:
    """Shared builder: every linear quantized, activations per-token dynamic
    or per-tensor static; norms untouched."""
    layers = []
    for l, lc in zip(p["layers"], calib.layers):
        def spec(w, stats, hadamard=False):
            if activation == "dynamic":
                return _dyn_spec(w, stats.samples, w_bits=w_bits,
                                 a_bits=a_bits, use_gptq=use_gptq,
                                 hadamard=hadamard)
            return _tensor_static_spec(w, stats.samples,
                                       float(stats.absmax.max()),
                                       w_bits=w_bits, a_bits=a_bits,
                                       use_gptq=use_gptq)

        layers.append({
            "attn_norm": {"g": l["attn_norm"], "quant": None},
            "q": spec(l["wq"], lc.attn_norm_out),
            "k": spec(l["wk"], lc.attn_norm_out),
            "v": spec(l["wv"], lc.attn_norm_out),
            "o": spec(l["wo"], lc.o_in),
            "ffn_norm": {"g": l["ffn_norm"], "quant": None},
            "gate": spec(l["w_gate"], lc.ffn_norm_out),
            "up": spec(l["w_up"], lc.ffn_norm_out),
            "down": spec(l["w_down"], lc.down_in,
                         hadamard=online_hadamard and activation == "dynamic"),
        })
    return _assemble(cfg, p, layers, method)


def rtn(cfg: M.ModelConfig, params, calib: C.Calibration, *, w_bits=4,
        a_bits=4) -> QuantModel:
    p = _np_params(params)
    return _build_token_or_tensor(cfg, p, calib, method="rtn",
                                  activation="dynamic", w_bits=w_bits,
                                  a_bits=a_bits, use_gptq=False,
                                  online_hadamard=False)


def smoothquant(cfg: M.ModelConfig, params, calib: C.Calibration, *,
                w_bits=4, a_bits=4, alpha=0.5, use_gptq=True) -> QuantModel:
    """Per-channel smoothing + per-tensor static activations."""
    p = _np_params(params)
    layers = []
    for l, lc in zip(p["layers"], calib.layers):
        def smoothed(stats, ws: list[np.ndarray]):
            a_max = np.maximum(stats.absmax, 1e-5)
            w_max = np.maximum(
                np.max(np.abs(np.concatenate(ws, axis=1)), axis=1), 1e-5)
            sm = np.maximum(a_max**alpha / w_max**(1 - alpha), 1e-5)
            return sm, stats.samples / sm, a_max / sm

        sm_a, xs_a, amax_a = smoothed(lc.attn_norm_out,
                                      [l["wq"], l["wk"], l["wv"]])
        sm_f, xs_f, amax_f = smoothed(lc.ffn_norm_out,
                                      [l["w_gate"], l["w_up"]])

        def ts(w, xs, amax):
            return _tensor_static_spec(w, xs, float(amax.max()),
                                       w_bits=w_bits, a_bits=a_bits,
                                       use_gptq=use_gptq)

        layers.append({
            "attn_norm": {"g": l["attn_norm"] / sm_a, "quant": None},
            "q": ts(sm_a[:, None] * l["wq"], xs_a, amax_a),
            "k": ts(sm_a[:, None] * l["wk"], xs_a, amax_a),
            "v": ts(sm_a[:, None] * l["wv"], xs_a, amax_a),
            "o": _tensor_static_spec(l["wo"], lc.o_in.samples,
                                     float(lc.o_in.absmax.max()),
                                     w_bits=w_bits, a_bits=a_bits,
                                     use_gptq=use_gptq),
            "ffn_norm": {"g": l["ffn_norm"] / sm_f, "quant": None},
            "gate": ts(sm_f[:, None] * l["w_gate"], xs_f, amax_f),
            "up": ts(sm_f[:, None] * l["w_up"], xs_f, amax_f),
            "down": _tensor_static_spec(l["w_down"], lc.down_in.samples,
                                        float(lc.down_in.absmax.max()),
                                        w_bits=w_bits, a_bits=a_bits,
                                        use_gptq=use_gptq),
        })
    return _assemble(cfg, p, layers, "smoothquant")


def omniquant_lite(cfg: M.ModelConfig, params, calib: C.Calibration, *,
                   w_bits=4, a_bits=4) -> QuantModel:
    """Grid-searched equivalent smoothing per layer + per-token dynamic."""
    p = _np_params(params)
    qa = qmax_for_bits(a_bits)
    layers = []
    for l, lc in zip(p["layers"], calib.layers):
        def best_alpha(stats, ws):
            wcat = np.concatenate(ws, axis=1)
            best, best_sm = np.inf, np.ones(stats.absmax.shape, np.float32)
            for alpha in (0.3, 0.45, 0.6, 0.75, 0.9):
                a_max = np.maximum(stats.absmax, 1e-5)
                w_max = np.maximum(np.max(np.abs(wcat), axis=1), 1e-5)
                sm = np.maximum(a_max**alpha / w_max**(1 - alpha), 1e-5)
                xs = stats.samples / sm
                s = np.maximum(np.max(np.abs(xs), axis=-1, keepdims=True) / qa,
                               1e-8)
                xq = np.clip(np.round(xs / s), -qa, qa) * s
                wsm = sm[:, None] * wcat
                wq = quantize_weight(wsm, bits=w_bits).dequant()
                err = float(np.sum((xq @ wq - stats.samples @ wcat) ** 2))
                if err < best:
                    best, best_sm = err, sm
            return best_sm

        sm_a = best_alpha(lc.attn_norm_out, [l["wq"], l["wk"], l["wv"]])
        sm_f = best_alpha(lc.ffn_norm_out, [l["w_gate"], l["w_up"]])

        def dyn(w, stats, sm=None):
            if sm is not None:
                w = sm[:, None] * w
                xs = stats.samples / sm
            else:
                xs = stats.samples
            return _dyn_spec(w, xs, w_bits=w_bits, a_bits=a_bits,
                             use_gptq=True)

        layers.append({
            "attn_norm": {"g": l["attn_norm"] / sm_a, "quant": None},
            "q": dyn(l["wq"], lc.attn_norm_out, sm_a),
            "k": dyn(l["wk"], lc.attn_norm_out, sm_a),
            "v": dyn(l["wv"], lc.attn_norm_out, sm_a),
            "o": dyn(l["wo"], lc.o_in),
            "ffn_norm": {"g": l["ffn_norm"] / sm_f, "quant": None},
            "gate": dyn(l["w_gate"], lc.ffn_norm_out, sm_f),
            "up": dyn(l["w_up"], lc.ffn_norm_out, sm_f),
            "down": dyn(l["w_down"], lc.down_in),
        })
    return _assemble(cfg, p, layers, "omniquant")


def qllm_lite(cfg: M.ModelConfig, params, calib: C.Calibration, *,
              w_bits=4, a_bits=4, theta_alpha=3.0) -> QuantModel:
    """Outlier-channel equalisation (channel-disassembly effect), dynamic."""
    p = _np_params(params)
    layers = []
    for l, lc in zip(p["layers"], calib.layers):
        def equalise(stats):
            am = stats.absmax
            t = float(np.mean(am) + theta_alpha * np.std(am))
            sm = np.maximum(am / t, 1.0).astype(np.float32)  # divide outliers
            return sm

        sm_a, sm_f = equalise(lc.attn_norm_out), equalise(lc.ffn_norm_out)

        def dyn(w, stats, sm=None):
            if sm is not None:
                w = sm[:, None] * w
                xs = stats.samples / sm
            else:
                xs = stats.samples
            return _dyn_spec(w, xs, w_bits=w_bits, a_bits=a_bits,
                             use_gptq=True)

        layers.append({
            "attn_norm": {"g": l["attn_norm"] / sm_a, "quant": None},
            "q": dyn(l["wq"], lc.attn_norm_out, sm_a),
            "k": dyn(l["wk"], lc.attn_norm_out, sm_a),
            "v": dyn(l["wv"], lc.attn_norm_out, sm_a),
            "o": dyn(l["wo"], lc.o_in),
            "ffn_norm": {"g": l["ffn_norm"] / sm_f, "quant": None},
            "gate": dyn(l["w_gate"], lc.ffn_norm_out, sm_f),
            "up": dyn(l["w_up"], lc.ffn_norm_out, sm_f),
            "down": dyn(l["w_down"], lc.down_in),
        })
    return _assemble(cfg, p, layers, "qllm")


def _rotated_model(cfg: M.ModelConfig, params, batches, rotation: np.ndarray):
    """Fold norms + rotation, then recalibrate on the rotated FP model."""
    folded = fold_norms(params)
    rot = H.fold_residual_rotation(folded, rotation)
    calib = C.calibrate(cfg, rot, batches)
    return rot, calib


def quarot(cfg: M.ModelConfig, params, batches: list[np.ndarray], *,
           w_bits=4, a_bits=4, online_hadamard=True, seed=0,
           activation="dynamic", sym=True, group=0,
           method_name=None) -> QuantModel:
    rot_m = H.random_hadamard_like(cfg.d_model, seed)
    p, calib = _rotated_model(cfg, params, batches, rot_m)
    name = method_name or ("quarot" if online_hadamard else "quarot_nh")
    if activation == "tensor_static":
        name = method_name or "quarot_static"
        return _build_token_or_tensor(cfg, p, calib, method=name,
                                      activation="tensor_static",
                                      w_bits=w_bits, a_bits=a_bits,
                                      use_gptq=True, online_hadamard=False)
    if sym and group == 0:
        return _build_token_or_tensor(cfg, p, calib, method=name,
                                      activation="dynamic", w_bits=w_bits,
                                      a_bits=a_bits, use_gptq=True,
                                      online_hadamard=online_hadamard)
    # Table 5 variants: asym / grouped weights.
    layers = []
    for l, lc in zip(p["layers"], calib.layers):
        def dyn(w, stats, hadamard=False):
            return _dyn_spec(w, stats.samples, w_bits=w_bits, a_bits=a_bits,
                             use_gptq=True, hadamard=hadamard, sym=sym,
                             group=group)
        layers.append({
            "attn_norm": {"g": l["attn_norm"], "quant": None},
            "q": dyn(l["wq"], lc.attn_norm_out),
            "k": dyn(l["wk"], lc.attn_norm_out),
            "v": dyn(l["wv"], lc.attn_norm_out),
            "o": dyn(l["wo"], lc.o_in),
            "ffn_norm": {"g": l["ffn_norm"], "quant": None},
            "gate": dyn(l["w_gate"], lc.ffn_norm_out),
            "up": dyn(l["w_up"], lc.ffn_norm_out),
            "down": dyn(l["w_down"], lc.down_in, hadamard=online_hadamard),
        })
    return _assemble(cfg, p, layers, name)


def _rotation_proxy_loss(cfg, params, batches, rotation, a_bits=4) -> float:
    """Cheap calibration loss for rotation selection (SpinQuant proxy)."""
    p, calib = _rotated_model(cfg, params, batches, rotation)
    qa = qmax_for_bits(a_bits)
    loss = 0.0
    for lc in calib.layers:
        for stats in (lc.attn_norm_out, lc.ffn_norm_out, lc.o_in, lc.down_in):
            xs = stats.samples
            s = np.maximum(np.max(np.abs(xs), axis=-1, keepdims=True) / qa, 1e-8)
            xq = np.clip(np.round(xs / s), -qa, qa) * s
            loss += float(np.sum((xq - xs) ** 2))
    return loss


def spinquant(cfg: M.ModelConfig, params, batches: list[np.ndarray], *,
              w_bits=4, a_bits=4, online_hadamard=True,
              n_candidates=6) -> QuantModel:
    """'Learned' rotation via candidate selection on calibration loss."""
    best_seed, best = 0, np.inf
    for seed in range(n_candidates):
        rot = H.random_hadamard_like(cfg.d_model, seed)
        l = _rotation_proxy_loss(cfg, params, batches, rot, a_bits)
        if l < best:
            best, best_seed = l, seed
    name = "spinquant" if online_hadamard else "spinquant_nh"
    return quarot(cfg, params, batches, w_bits=w_bits, a_bits=a_bits,
                  online_hadamard=online_hadamard, seed=best_seed,
                  method_name=name)
