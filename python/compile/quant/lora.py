"""Quantization compensation (paper §4.3).

Low-rank matrices A (n×r), B (r×j) are learned per linear layer to
minimize the reconstruction error between the layer's original FP output
and its quantized output; the deployed weight is q(W + AB) — the
compensation is *absorbed before* weight quantization, so it costs nothing
at inference.

The paper trains A, B with 15 epochs of LoRA fine-tuning on 256 samples.
At our scale the same objective has a cheap exact solution: with X the
calibration inputs of the layer and R the current quantization residual,
    min_{ΔW} ‖X ΔW − R‖²   ⇒   ΔW = (XᵀX + λI)⁻¹ Xᵀ R   (ridge),
rank-restricted by truncated SVD to r, alternated with re-quantization for
a few rounds (quantizing W+AB changes the residual). This is the same
objective the paper optimizes, solved in closed form — documented as a
substitution in DESIGN.md §2.
"""

from __future__ import annotations

import numpy as np

from .quantizer import QWeight
from .gptq import gptq_quantize


def _ridge_lowrank(x: np.ndarray, resid: np.ndarray, rank: int,
                   lam_frac: float = 0.01) -> np.ndarray:
    """argmin_ΔW ‖X ΔW − resid‖² + λ‖ΔW‖², truncated to ``rank``."""
    n = x.shape[1]
    h = x.T @ x
    lam = lam_frac * float(np.mean(np.diag(h))) + 1e-8
    dw = np.linalg.solve(h + lam * np.eye(n), x.T @ resid)
    u, s, vt = np.linalg.svd(dw, full_matrices=False)
    r = min(rank, len(s))
    return (u[:, :r] * s[:r]) @ vt[:r]


def compensate(w_folded: np.ndarray, x_in: np.ndarray, x_ref: np.ndarray,
               w_ref: np.ndarray, quantize, rank: int = 8,
               rounds: int = 3) -> tuple[QWeight, np.ndarray]:
    """Learn the low-rank compensation for one linear layer.

    w_folded: the weight actually being quantized (scales folded, possibly
      reconstructed), (n, j).
    x_in: calibration inputs *of the quantized path* (integer activations
      for static layers, fp inputs for dynamic layers), (S, n).
    x_ref / w_ref: the FP reference input and weight producing the target
      output X_ref @ W_ref, (S, n_ref) / (n_ref, j).
    quantize: callable W -> QWeight (the GPTQ/RTN config in use).

    Returns (final QWeight of W+AB, the dense AB correction).
    """
    target = x_ref @ w_ref
    ab = np.zeros_like(w_folded)
    qw = quantize(w_folded)

    def obj(q):
        d = x_in @ q.dequant() - target
        return float(np.sum(d * d))

    # Keep the best round: re-quantizing W+AB can regress (the correction
    # may push absmax up and coarsen the scale), so this is early stopping
    # on the same reconstruction objective the paper fine-tunes.
    best_qw, best_ab, best = qw, np.zeros_like(ab), obj(qw)
    for _ in range(rounds):
        out = x_in @ qw.dequant()
        resid = target - out
        ab = ab + _ridge_lowrank(x_in, resid, rank)
        qw = quantize(w_folded + ab)
        e = obj(qw)
        if e < best:
            best_qw, best_ab, best = qw, ab.copy(), e
    return best_qw, best_ab


def default_gptq_quantizer(x_samples: np.ndarray, bits: int = 4,
                           sym: bool = True, group: int = 0):
    """Quantizer factory shared by pipeline stages."""
    def q(w: np.ndarray) -> QWeight:
        return gptq_quantize(w, x_samples, bits=bits, sym=sym, group=group)
    return q
