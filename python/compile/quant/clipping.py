"""Adaptive clipping (paper §4.2, Eq. 7; ablated in Table 7 / Fig. 7).

Two regimes:

* **Per-channel adaptive clipping** for the statically quantized layers
  (qkv / gate / up inputs). For each channel k we pick the clip ratio r
  minimizing   L_k(r) = ‖X̂_k(r) − X_k‖² + ‖Ŵ^X_k(r) − W^X_k‖²
  — activation round-off under the clipped scale plus the quantization
  error of the *folded* weight row s_k(r)·W_k (the dequant-migration
  side-effect the clipping is balancing).
* **Uniform per-token clipping** for the dynamic layers (out / down
  inputs): one ratio per layer minimizing the layer *output* MSE on the
  calibration sample, searched on a grid.

``channel_clipping`` (the Table 7 middle row) is the naive variant that
only minimizes the activation term.
"""

from __future__ import annotations

import numpy as np

from .quantizer import qmax_for_bits, quantize_weight, round_half_away

CLIP_GRID = np.linspace(0.5, 1.0, 11)


def _act_error(col: np.ndarray, scale: float, qmax: int) -> float:
    xq = np.clip(round_half_away(col / scale), -qmax, qmax)
    d = xq * scale - col
    return float(np.sum(d * d))


def _weight_row_error(row_folded: np.ndarray, qmax: int) -> float:
    """Per-row quantization proxy for the folded-weight term of Eq. (7).

    Per-column scales couple all channels; a per-row absmax proxy keeps the
    search per-channel separable while preserving the effect that matters:
    larger folded rows quantize worse.
    """
    s = max(np.max(np.abs(row_folded)) / qmax, 1e-8)
    wq = np.clip(round_half_away(row_folded / s), -qmax, qmax)
    d = wq * s - row_folded
    return float(np.sum(d * d))


def adaptive_channel_clip(samples: np.ndarray, absmax: np.ndarray,
                          w_rows: np.ndarray, a_bits: int = 4,
                          w_bits: int = 4) -> np.ndarray:
    """Per-channel clip ratios for a statically quantized input.

    samples: (S, d) calibration activations (post-norm); absmax: (d,);
    w_rows: (d, j) the concatenated weight the activation feeds (e.g.
    [wq|wk|wv]). Returns ratios (d,).
    """
    qa, qw = qmax_for_bits(a_bits), qmax_for_bits(w_bits)
    d = samples.shape[1]
    ratios = np.ones(d, dtype=np.float32)
    for k in range(d):
        col = samples[:, k]
        base = max(absmax[k], 1e-8)
        best, best_r = np.inf, 1.0
        for r in CLIP_GRID:
            scale = base * r / qa
            loss = _act_error(col, scale, qa)
            loss += _weight_row_error(scale * qa * w_rows[k], qw)
            if loss < best:
                best, best_r = loss, r
        ratios[k] = best_r
    return ratios


def channel_clip_act_only(samples: np.ndarray, absmax: np.ndarray,
                          a_bits: int = 4) -> np.ndarray:
    """Naive per-channel clipping: activation MSE only (Table 7 row 2)."""
    qa = qmax_for_bits(a_bits)
    d = samples.shape[1]
    ratios = np.ones(d, dtype=np.float32)
    for k in range(d):
        col = samples[:, k]
        base = max(absmax[k], 1e-8)
        errs = [_act_error(col, base * r / qa, qa) for r in CLIP_GRID]
        ratios[k] = CLIP_GRID[int(np.argmin(errs))]
    return ratios


def uniform_token_clip(samples: np.ndarray, w: np.ndarray, a_bits: int = 4,
                       w_bits: int = 4) -> float:
    """Uniform clip ratio for a per-token dynamic layer (out / down).

    Minimizes ‖Q(X;r) @ Ŵ − X @ W‖² over the grid, with Ŵ the RTN-int4
    weight — i.e. the difference between the layer output before and after
    quantization (paper §4.2 last paragraph).
    """
    qa = qmax_for_bits(a_bits)
    ref = samples @ w
    wdq = quantize_weight(w, bits=w_bits).dequant()
    best, best_r = np.inf, 1.0
    for r in CLIP_GRID:
        s = np.maximum(np.max(np.abs(samples), axis=-1, keepdims=True) * r / qa,
                       1e-8)
        xq = np.clip(round_half_away(samples / s), -qa, qa)
        out = (xq * s) @ wdq
        err = float(np.sum((out - ref) ** 2))
        if err < best:
            best, best_r = err, float(r)
    return best_r
