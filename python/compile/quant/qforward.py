"""Quantized model structure + JAX quantized forward.

This module defines the **canonical QuantModel schema** shared with the
Rust engine (rust/src/engine mirrors it; qmod.py serializes it):

QuantModel
├── config: ModelConfig fields
├── method: str
├── embed (v,d) f32 — outlier gain (and any residual rotation) folded in
├── final_norm (d,) f32, lm_head (d,v) f32 — kept FP (standard practice)
└── layers[L]:
    ├── attn_norm / ffn_norm: NormSpec
    │     g (d,) f32            — γ, or merged γ/s when quant is set
    │     quant: None | {qmax, recon_idx (d,) i32 | None}
    └── q,k,v,o,gate,up,down: LinearSpec
          mode  "fp"            w (n,j) f32
                "static"        qw: QWeight — input is the integer
                                activations the merged norm emits (Eq. 5)
                "tensor_static" qw + a_scale (scalar), a_qmax — SmoothQuant
                "channel_static" qw + a_scale (n,), a_qmax,
                                recon_idx (n,) i32 | None — per-channel
                                static activation quant; dequant folded
                                into the weight columns (format 3)
                "dynamic"       qw + a_qmax, a_clip, hadamard — per-token
exactly one of {w, qw} present per linear.

The JAX forward here is the *reference semantics* for the Rust engine
(parity-tested via artifact goldens) and the source of the quantized HLO
artifacts. ``use_pallas=True`` routes the three hot ops through the L1
Pallas kernels so they lower into the exported HLO.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .. import model as M
from ..kernels import ref as KREF
from .quantizer import QWeight

QuantModel = dict[str, Any]


def _norm_apply(norm: dict, x: jax.Array, use_pallas: bool) -> jax.Array:
    """Apply a NormSpec; returns fp32 or integer-valued activations."""
    g = jnp.asarray(norm["g"])
    q = norm.get("quant")
    if q is None:
        return M.rmsnorm(x, g)
    qmax = q["qmax"]
    recon = q.get("recon_idx")
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    if use_pallas:
        from ..kernels import rmsnorm_quant as KP
        if recon is not None:
            out = KP.rmsnorm_quant_recon(x2, g, jnp.asarray(recon), qmax=qmax)
        else:
            out = KP.rmsnorm_quant(x2, g, qmax=qmax)
    else:
        out = KREF.rmsnorm_quant_ref(x2, g, qmax)
        if recon is not None:
            out = out[..., jnp.asarray(recon)]
    return out.reshape(shape)


def _static_scale_zero(qw: QWeight):
    """Flatten grouped scales to jnp; returns (scale (G,j), zero or None)."""
    scale = jnp.asarray(qw.scale)
    zero = None if qw.zero is None else jnp.asarray(qw.zero, jnp.float32)
    return scale, zero


def _int_matmul(xq: jax.Array, qw: QWeight, use_pallas: bool) -> jax.Array:
    """(xq @ W_int) with per-(group,column) rescale; zero-point corrected."""
    n, j = qw.wq.shape
    g = qw.group or n
    scale, zero = _static_scale_zero(qw)
    wq = jnp.asarray(qw.wq, jnp.float32)
    if g == n:
        if use_pallas:
            from ..kernels import qsm_matmul as KP
            if zero is None:
                return KP.qsm_matmul(xq, wq, scale[0])
            return KP.qsm_matmul_asym(xq, wq, zero[0], scale[0])
        if zero is None:
            return KREF.qsm_matmul_ref(xq, wq, scale[0])
        return KREF.qsm_matmul_asym_ref(xq, wq, zero[0], scale[0])
    # Grouped: accumulate per group then rescale (engine mirrors this).
    xg = xq.reshape(xq.shape[0], n // g, g)
    wg = wq.reshape(n // g, g, j)
    acc = jnp.einsum("mkg,kgj->mkj", xg, wg)
    if zero is not None:
        rowsum = jnp.sum(xg, axis=-1)  # (m, G)
        acc = acc - rowsum[..., None] * zero[None]
    return jnp.sum(acc * scale[None], axis=1)


def _linear_apply(spec: dict, x: jax.Array, use_pallas: bool) -> jax.Array:
    """Apply a LinearSpec to (..., n) activations."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    mode = spec["mode"]
    if mode == "fp":
        out = x2 @ jnp.asarray(spec["w"])
    elif mode == "static":
        out = _int_matmul(x2, spec["qw"], use_pallas)
    elif mode == "tensor_static":
        a_scale = spec["a_scale"]
        qm = spec["a_qmax"]
        xq = jnp.clip(KREF.round_half_away(x2 / a_scale), -qm, qm)
        out = _int_matmul(xq, spec["qw"], use_pallas) * a_scale
    elif mode == "channel_static":
        # Per-channel static quantize, then the dimension-reconstruction
        # gather; the activation dequant is already folded into the
        # weight columns (Eq. 5), so no rescale after the matmul.
        s = jnp.asarray(spec["a_scale"])
        qm = spec["a_qmax"]
        xq = jnp.clip(KREF.round_half_away(x2 / s), -qm, qm)
        recon = spec.get("recon_idx")
        if recon is not None:
            xq = xq[..., jnp.asarray(recon)]
        out = _int_matmul(xq, spec["qw"], use_pallas)
    elif mode == "dynamic":
        if spec.get("hadamard"):
            x2 = KREF.hadamard_block64_ref(x2)
        qm = spec["a_qmax"]
        clip = spec.get("a_clip", 1.0)
        if use_pallas and spec["qw"].group == 0 and spec["qw"].zero is None:
            from ..kernels import qsm_matmul as KP
            out = KP.dyn_quant_matmul(x2, jnp.asarray(spec["qw"].wq, jnp.float32),
                                      jnp.asarray(spec["qw"].scale[0]),
                                      qmax=qm, clip=clip)
        else:
            s = jnp.maximum(jnp.max(jnp.abs(x2), axis=-1, keepdims=True)
                            * clip / qm, 1e-8)
            xq = jnp.clip(KREF.round_half_away(x2 / s), -qm, qm)
            out = _int_matmul(xq, spec["qw"], use_pallas) * s
    else:
        raise ValueError(mode)
    return out.reshape(*shape[:-1], out.shape[-1])


def quant_forward(cfg: M.ModelConfig, qm: QuantModel, tokens: jax.Array,
                  use_pallas: bool = False) -> jax.Array:
    """Quantized forward: tokens (B,T) -> logits (B,T,V)."""
    x = jnp.asarray(qm["embed"])[tokens] * jnp.asarray(qm["outlier_gain"])
    cos, sin = M.rope_angles(cfg, jnp.arange(tokens.shape[1]))
    B, T, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    for layer in qm["layers"]:
        h = _norm_apply(layer["attn_norm"], x, use_pallas)
        q = _linear_apply(layer["q"], h, use_pallas).reshape(B, T, H, hd)
        k = _linear_apply(layer["k"], h, use_pallas).reshape(B, T, H, hd)
        v = _linear_apply(layer["v"], h, use_pallas).reshape(B, T, H, hd)
        q, k = M.apply_rope(q, cos, sin), M.apply_rope(k, cos, sin)
        attn = M.attention(q, k, v).reshape(B, T, d)
        x = x + _linear_apply(layer["o"], attn, use_pallas)
        h = _norm_apply(layer["ffn_norm"], x, use_pallas)
        gate = _linear_apply(layer["gate"], h, use_pallas)
        up = _linear_apply(layer["up"], h, use_pallas)
        x = x + _linear_apply(layer["down"], jax.nn.silu(gate) * up, use_pallas)
    x = M.rmsnorm(x, jnp.asarray(qm["final_norm"]))
    return x @ jnp.asarray(qm["lm_head"])


def quant_decode_step(cfg: M.ModelConfig, qm: QuantModel, token: jax.Array,
                      pos: jax.Array, kcache: jax.Array, vcache: jax.Array,
                      use_pallas: bool = False):
    """Quantized single-token decode with KV cache (mirrors model.decode_step)."""
    B = token.shape[0]
    H, hd, d = cfg.n_heads, cfg.head_dim, cfg.d_model
    maxT = kcache.shape[2]
    x = jnp.asarray(qm["embed"])[token][:, None, :] * jnp.asarray(qm["outlier_gain"])
    cos, sin = M.rope_angles(cfg, pos[None])
    visible = (jnp.arange(maxT) <= pos)[None, None, None, :]
    new_k, new_v = kcache, vcache
    for li, layer in enumerate(qm["layers"]):
        h = _norm_apply(layer["attn_norm"], x, use_pallas)
        q = _linear_apply(layer["q"], h, use_pallas).reshape(B, 1, H, hd)
        k = _linear_apply(layer["k"], h, use_pallas).reshape(B, 1, H, hd)
        v = _linear_apply(layer["v"], h, use_pallas).reshape(B, 1, H, hd)
        q, k = M.apply_rope(q, cos, sin), M.apply_rope(k, cos, sin)
        kc = jax.lax.dynamic_update_slice(new_k[li], k, (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(new_v[li], v, (0, pos, 0, 0))
        new_k = new_k.at[li].set(kc)
        new_v = new_v.at[li].set(vc)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kc) / np.sqrt(hd)
        scores = jnp.where(visible, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhqk,bkhd->bqhd", probs, vc).reshape(B, 1, d)
        x = x + _linear_apply(layer["o"], attn, use_pallas)
        hn = _norm_apply(layer["ffn_norm"], x, use_pallas)
        gate = _linear_apply(layer["gate"], hn, use_pallas)
        up = _linear_apply(layer["up"], hn, use_pallas)
        x = x + _linear_apply(layer["down"], jax.nn.silu(gate) * up, use_pallas)
    x = M.rmsnorm(x, jnp.asarray(qm["final_norm"]))
    logits = (x @ jnp.asarray(qm["lm_head"]))[:, 0, :]
    return logits, new_k, new_v


def fp_quant_model(cfg: M.ModelConfig, params) -> QuantModel:
    """Wrap FP32 params in the QuantModel schema (the FP16 baseline row)."""
    def lin(w):
        return {"mode": "fp", "w": np.asarray(w, np.float32)}

    return {
        "config": cfg,
        "method": "fp16",
        "embed": np.asarray(params["embed"], np.float32),
        "outlier_gain": np.asarray(params["outlier_gain"], np.float32),
        "final_norm": np.asarray(params["final_norm"], np.float32),
        "lm_head": np.asarray(params["lm_head"], np.float32),
        "layers": [
            {
                "attn_norm": {"g": np.asarray(l["attn_norm"], np.float32),
                              "quant": None},
                "q": lin(l["wq"]), "k": lin(l["wk"]), "v": lin(l["wv"]),
                "o": lin(l["wo"]),
                "ffn_norm": {"g": np.asarray(l["ffn_norm"], np.float32),
                             "quant": None},
                "gate": lin(l["w_gate"]), "up": lin(l["w_up"]),
                "down": lin(l["w_down"]),
            }
            for l in params["layers"]
        ],
    }
