"""The MergeQuant pipeline (paper §4) and the method registry.

``mergequant()`` runs the full offline flow on a trained FP model:

1. channel-wise calibration of the RMSNorm outputs (§4.1);
2. adaptive clipping of the per-channel scales (§4.2, Eq. 7);
3. dimension reconstruction of the scale vector (§4.2, Eq. 6);
4. Quantization Step Migration: merge γ/s into the norm multiplier
   (Eq. 4) and fold the per-channel σ into the weight rows (Eq. 5);
5. GPTQ per-column weight quantization of the folded weights;
6. low-rank quantization compensation (§4.3);
7. out/down projections: per-token dynamic with a uniform searched clip,
   optionally behind an online block-Hadamard (the non-``_nh`` variant) —
   or, with ``static_od=True`` (the ``mergequant_static`` registry row),
   per-channel *static* activation quantization with the dequant folded
   into the weight columns, extending the QSM discipline end to end
   (format-3 ``.qmod`` bundles; DESIGN.md §17).

Every stage is individually toggleable — Table 4's ablation rows and
Fig. 1's calibration comparison are produced with the same entry point.
"""

from __future__ import annotations

import time

import numpy as np

from .. import model as M
from . import baselines as B
from . import calibration as C
from . import clipping as CL
from . import hadamard as H
from .gptq import GptqContext, gptq_quantize
from .lora import compensate
from .quantizer import qmax_for_bits, quantize_weight, round_half_away
from .reconstruct import Reconstruction, identity_reconstruction, reconstruct
from .qforward import QuantModel, fp_quant_model

DEFAULT_ALPHA = {"tiny-llama-s": 5.0, "tiny-llama-m": 5.0,
                 "tiny-llama-l": 5.0, "tiny-llama3": 2.0}


def _static_branch(norm_g: np.ndarray, stats: C.TensorStats,
                   weights: dict[str, np.ndarray], *, a_bits: int,
                   w_bits: int, w_sym: bool, w_group: int, clipping: str,
                   do_reconstruct: bool, alpha: float, lora_rank: int,
                   use_gptq: bool):
    """Build the merged norm + static LinearSpecs for one norm's fan-out.

    weights: name -> original FP weight (d, j), all sharing the norm output.
    Returns (norm_spec, {name: linear_spec}, report dict).
    """
    qa = qmax_for_bits(a_bits)
    absmax = np.maximum(stats.absmax, 1e-6)
    wcat = np.concatenate(list(weights.values()), axis=1)

    # --- adaptive clipping of the per-channel scales (Eq. 7) ---
    if clipping == "adaptive":
        ratios = CL.adaptive_channel_clip(stats.samples, absmax, wcat,
                                          a_bits=a_bits, w_bits=w_bits)
    elif clipping == "channel":
        ratios = CL.channel_clip_act_only(stats.samples, absmax, a_bits=a_bits)
    else:
        ratios = np.ones_like(absmax)
    s = absmax * ratios / qa  # per-channel static scales s_k

    # --- dimension reconstruction (Eq. 6 + pruning schemes) ---
    recon: Reconstruction = (reconstruct(s, stats.sqsum, alpha=alpha)
                             if do_reconstruct else identity_reconstruction(s))

    # --- quantization migration: merged multiplier γ/s (Eq. 4) ---
    g_merged = norm_g / s
    norm_spec = {"g": g_merged.astype(np.float32),
                 "quant": {"qmax": qa,
                           "recon_idx": (recon.recon_idx
                                         if do_reconstruct else None)}}

    # Integer activations the static GEMMs will see (for GPTQ/LoRA).
    xq = np.clip(round_half_away(stats.samples / s), -qa, qa)
    xq_rec = recon.apply_to_activation(xq)

    specs = {}
    ctx = GptqContext(xq_rec) if use_gptq else None
    for name, w in weights.items():
        w_folded = recon.apply_to_weight(w)  # σ_i · W[src_i, :]  (Eq. 5)

        def quantize(mat):
            if use_gptq:
                return gptq_quantize(mat, xq_rec, bits=w_bits, sym=w_sym,
                                     group=w_group, ctx=ctx)
            return quantize_weight(mat, bits=w_bits, sym=w_sym, group=w_group)

        if lora_rank > 0:
            qw, _ = compensate(w_folded, xq_rec, stats.samples, w,
                               quantize, rank=lora_rank, rounds=2)
        else:
            qw = quantize(w_folded)
        specs[name] = {"mode": "static", "qw": qw}

    report = {"threshold": recon.threshold,
              "n_strong": int(len(recon.strong)),
              "n_split_extra": int(recon.n_split_extra),
              "clip_ratios": ratios.tolist()}
    return norm_spec, specs, report


def _channel_static_branch(w: np.ndarray, stats: C.TensorStats, *,
                           a_bits: int, w_bits: int, w_sym: bool,
                           w_group: int, clipping: str,
                           do_reconstruct: bool, alpha: float,
                           lora_rank: int, use_gptq: bool):
    """Per-channel *static* LinearSpec for out/down (DESIGN.md §17).

    Same calibration → clip → reconstruct recipe as ``_static_branch``,
    but for a single linear whose input is an FP activation (attention
    output / SiLU product), not a norm output: the quantize scales stay
    in the spec (``a_scale``, applied per input channel at runtime with
    precomputed multipliers) while the matching dequant factors are
    folded into the weight rows offline (Eq. 5), so the runtime pays
    quantize + integer GEMM + column epilogue — zero per-token scale
    math. Returns (linear_spec, mean clip ratio).
    """
    qa = qmax_for_bits(a_bits)
    absmax = np.maximum(stats.absmax, 1e-6)
    if clipping == "adaptive":
        ratios = CL.adaptive_channel_clip(stats.samples, absmax, w,
                                          a_bits=a_bits, w_bits=w_bits)
    elif clipping == "channel":
        ratios = CL.channel_clip_act_only(stats.samples, absmax,
                                          a_bits=a_bits)
    else:
        ratios = np.ones_like(absmax)
    s = absmax * ratios / qa

    recon: Reconstruction = (reconstruct(s, stats.sqsum, alpha=alpha)
                             if do_reconstruct else identity_reconstruction(s))

    # Quantize-then-gather, the exact order the engines replay (the Rust
    # forward fuses both into one pass over the activation row).
    xq = np.clip(round_half_away(stats.samples / s), -qa, qa)
    xq_rec = recon.apply_to_activation(xq)
    w_folded = recon.apply_to_weight(w)  # σ_i · W[src_i, :]  (Eq. 5)

    ctx = GptqContext(xq_rec) if use_gptq else None

    def quantize(mat):
        if use_gptq:
            return gptq_quantize(mat, xq_rec, bits=w_bits, sym=w_sym,
                                 group=w_group, ctx=ctx)
        return quantize_weight(mat, bits=w_bits, sym=w_sym, group=w_group)

    if lora_rank > 0:
        qw, _ = compensate(w_folded, xq_rec, stats.samples, w, quantize,
                           rank=lora_rank, rounds=2)
    else:
        qw = quantize(w_folded)
    spec = {"mode": "channel_static", "qw": qw, "a_qmax": qa,
            "a_scale": s.astype(np.float32),
            "recon_idx": recon.recon_idx if do_reconstruct else None}
    return spec, float(np.mean(ratios))


def _dynamic_branch(w: np.ndarray, stats: C.TensorStats, *, a_bits: int,
                    w_bits: int, w_sym: bool, w_group: int, clipping: str,
                    hadamard: bool, lora_rank: int, use_gptq: bool):
    """Per-token dynamic LinearSpec for out/down (§4.2 last paragraph)."""
    x = stats.samples
    w_eff = w
    if hadamard:
        w_eff = H.fold_online_hadamard_into_weight(w)
        x = H.fwht_block64(x)
    clip = (CL.uniform_token_clip(x, w_eff, a_bits=a_bits, w_bits=w_bits)
            if clipping != "none" else 1.0)

    ctx = GptqContext(x) if use_gptq else None

    def quantize(mat):
        if use_gptq:
            return gptq_quantize(mat, x, bits=w_bits, sym=w_sym,
                                 group=w_group, ctx=ctx)
        return quantize_weight(mat, bits=w_bits, sym=w_sym, group=w_group)

    if lora_rank > 0:
        qw, _ = compensate(w_eff, x, x, w_eff, quantize, rank=lora_rank,
                           rounds=2)
    else:
        qw = quantize(w_eff)
    return {"mode": "dynamic", "qw": qw, "a_qmax": qmax_for_bits(a_bits),
            "a_clip": float(clip), "hadamard": bool(hadamard)}, clip


def mergequant(cfg: M.ModelConfig, params, batches: list[np.ndarray], *,
               a_bits: int = 4, w_bits: int = 4, w_sym: bool = True,
               w_group: int = 0, hadamard: bool = True,
               clipping: str = "adaptive", do_reconstruct: bool = True,
               lora_rank: int = 8, use_gptq: bool = True,
               alpha: float | None = None, static_od: bool = False,
               calib: C.Calibration | None = None,
               collect_report: dict | None = None) -> QuantModel:
    """Full MergeQuant (defaults) or any ablation of it (Table 4, 5, 7).

    ``static_od=True`` swaps the per-token dynamic out/down projections
    for the per-channel static W4A4 path (``channel_static`` specs,
    format-3 bundles); ``hadamard`` is then ignored — the static scales
    are calibrated on the un-rotated activations.
    """
    alpha = DEFAULT_ALPHA.get(cfg.name, 5.0) if alpha is None else alpha
    p = B._np_params(params)
    t0 = time.time()
    if calib is None:
        calib = C.calibrate(cfg, p, batches)
    calib_time = time.time() - t0

    t1 = time.time()
    layers = []
    report = {"layers": [], "calib_seconds": calib_time}
    for l, lc in zip(p["layers"], calib.layers):
        attn_norm, attn_specs, rep_a = _static_branch(
            l["attn_norm"], lc.attn_norm_out,
            {"q": l["wq"], "k": l["wk"], "v": l["wv"]},
            a_bits=a_bits, w_bits=w_bits, w_sym=w_sym, w_group=w_group,
            clipping=clipping, do_reconstruct=do_reconstruct, alpha=alpha,
            lora_rank=lora_rank, use_gptq=use_gptq)
        ffn_norm, ffn_specs, rep_f = _static_branch(
            l["ffn_norm"], lc.ffn_norm_out,
            {"gate": l["w_gate"], "up": l["w_up"]},
            a_bits=a_bits, w_bits=w_bits, w_sym=w_sym, w_group=w_group,
            clipping=clipping, do_reconstruct=do_reconstruct, alpha=alpha,
            lora_rank=lora_rank, use_gptq=use_gptq)
        if static_od:
            o_spec, o_clip = _channel_static_branch(
                l["wo"], lc.o_in, a_bits=a_bits, w_bits=w_bits,
                w_sym=w_sym, w_group=w_group, clipping=clipping,
                do_reconstruct=do_reconstruct, alpha=alpha,
                lora_rank=lora_rank, use_gptq=use_gptq)
            down_spec, down_clip = _channel_static_branch(
                l["w_down"], lc.down_in, a_bits=a_bits, w_bits=w_bits,
                w_sym=w_sym, w_group=w_group, clipping=clipping,
                do_reconstruct=do_reconstruct, alpha=alpha,
                lora_rank=lora_rank, use_gptq=use_gptq)
        else:
            o_spec, o_clip = _dynamic_branch(
                l["wo"], lc.o_in, a_bits=a_bits, w_bits=w_bits,
                w_sym=w_sym, w_group=w_group, clipping=clipping,
                hadamard=hadamard, lora_rank=lora_rank, use_gptq=use_gptq)
            down_spec, down_clip = _dynamic_branch(
                l["w_down"], lc.down_in, a_bits=a_bits, w_bits=w_bits,
                w_sym=w_sym, w_group=w_group, clipping=clipping,
                hadamard=hadamard, lora_rank=lora_rank, use_gptq=use_gptq)
        layers.append({
            "attn_norm": attn_norm, **attn_specs, "o": o_spec,
            "ffn_norm": ffn_norm, **ffn_specs, "down": down_spec,
        })
        report["layers"].append({"attn": rep_a, "ffn": rep_f,
                                 "o_clip": o_clip, "down_clip": down_clip})
    report["quantize_seconds"] = time.time() - t1
    if collect_report is not None:
        collect_report.update(report)

    if static_od:
        name = "mergequant_static"
    else:
        name = "mergequant" if hadamard else "mergequant_nh"
    qm = B._assemble(cfg, p, layers, name)
    # Static INT8 KV-cache scales from the same calibration corpus — the
    # format-2 schema carries them so the serving engine never computes a
    # scale at runtime (`kv_cache=int8`, DESIGN.md §10).
    if calib.layers and calib.layers[0].k_rope is not None:
        qm["kv"] = C.kv_scales_from_calib(cfg, calib)
    return qm


# ---------------------------------------------------------------------------
# Method registry — every Table 1 / Table 4 / Table 5 / Fig 1 configuration
# ---------------------------------------------------------------------------

def build_method(name: str, cfg: M.ModelConfig, params,
                 batches: list[np.ndarray],
                 calib: C.Calibration | None = None) -> QuantModel:
    """Build a QuantModel by method name.

    ``calib`` (FP-model calibration) is reused across non-rotated methods;
    rotated methods recalibrate internally on the rotated model.
    """
    def need_calib() -> C.Calibration:
        nonlocal calib
        if calib is None:
            calib = C.calibrate(cfg, params, batches)
        return calib

    if name == "fp16":
        return fp_quant_model(cfg, params)
    if name == "rtn":
        return B.rtn(cfg, params, need_calib())
    if name == "smoothquant":
        return B.smoothquant(cfg, params, need_calib())
    if name == "omniquant":
        return B.omniquant_lite(cfg, params, need_calib())
    if name == "qllm":
        return B.qllm_lite(cfg, params, need_calib())
    if name == "quarot":
        return B.quarot(cfg, params, batches, online_hadamard=True)
    if name == "quarot_nh":
        return B.quarot(cfg, params, batches, online_hadamard=False)
    if name == "quarot_static":
        return B.quarot(cfg, params, batches, activation="tensor_static")
    if name == "spinquant":
        return B.spinquant(cfg, params, batches, online_hadamard=True)
    if name == "spinquant_nh":
        return B.spinquant(cfg, params, batches, online_hadamard=False)
    if name == "mergequant":
        return mergequant(cfg, params, batches, hadamard=True, calib=calib)
    if name == "mergequant_nh":
        return mergequant(cfg, params, batches, hadamard=False, calib=calib)
    if name == "mergequant_static":
        # End-to-end static W4A4: o/down go per-channel static instead of
        # per-token dynamic (PR-9 serving path, DESIGN.md §17).
        return mergequant(cfg, params, batches, hadamard=False,
                          static_od=True, calib=calib)
    # --- Table 4 ablation rows ---
    if name == "mq_qsm_only":
        return mergequant(cfg, params, batches, hadamard=False,
                          clipping="none", lora_rank=0, calib=calib)
    if name == "mq_qsm_clip":
        return mergequant(cfg, params, batches, hadamard=False,
                          clipping="adaptive", lora_rank=0, calib=calib)
    # --- Table 7 clipping rows ---
    if name == "mq_noclip":
        return mergequant(cfg, params, batches, hadamard=True,
                          clipping="none", lora_rank=0, calib=calib)
    if name == "mq_channelclip":
        return mergequant(cfg, params, batches, hadamard=True,
                          clipping="channel", lora_rank=0, calib=calib)
    if name == "mq_adaptiveclip":
        return mergequant(cfg, params, batches, hadamard=True,
                          clipping="adaptive", lora_rank=0, calib=calib)
    # --- Table 5 rows (W3A4) ---
    if name == "quarot_w3_asym":
        return B.quarot(cfg, params, batches, w_bits=3, sym=False)
    if name == "quarot_w3_group":
        return B.quarot(cfg, params, batches, w_bits=3, group=64)
    if name == "mergequant_w3_asym":
        return mergequant(cfg, params, batches, w_bits=3, w_sym=False,
                          calib=calib)
    if name == "mergequant_w3_group":
        return mergequant(cfg, params, batches, w_bits=3, w_group=64,
                          calib=calib)
    # --- Fig 1 calibration variants ---
    if name == "pertensor_static":
        p = B._np_params(params)
        return B._build_token_or_tensor(
            cfg, p, need_calib(), method=name, activation="tensor_static",
            w_bits=4, a_bits=4, use_gptq=True, online_hadamard=False)
    if name == "pertoken_dynamic":
        return B.rtn(cfg, params, need_calib())
    if name == "pertoken_dynamic_rot":
        return B.quarot(cfg, params, batches, online_hadamard=False,
                        method_name=name)
    if name == "perchannel_static":
        return mergequant(cfg, params, batches, hadamard=False,
                          clipping="none", lora_rank=0, calib=calib)
    raise ValueError(f"unknown method {name!r}")


TABLE1_METHODS = ["fp16", "smoothquant", "omniquant", "qllm", "quarot_nh",
                  "spinquant_nh", "mergequant_nh", "quarot", "spinquant",
                  "mergequant"]
TABLE4_METHODS = ["fp16", "quarot_static", "mq_qsm_only", "mq_qsm_clip",
                  "mergequant"]
TABLE5_METHODS = ["fp16", "quarot_w3_asym", "quarot_w3_group",
                  "mergequant_w3_asym", "mergequant_w3_group"]
TABLE7_METHODS = ["fp16", "mq_noclip", "mq_channelclip", "mq_adaptiveclip"]
FIG1_METHODS = ["fp16", "pertensor_static", "pertoken_dynamic",
                "pertoken_dynamic_rot", "perchannel_static", "mergequant_nh"]
