"""MergeQuant quantization pipeline (build-time).

Submodules: quantizer (primitives), calibration, reconstruct (dimension
reconstruction), clipping, gptq, lora (compensation), hadamard (rotations),
baselines, pipeline (MergeQuant + method registry), qforward (quantized
forward / QuantModel schema).
"""
