"""Rotation utilities for the QuaRot / SpinQuant baselines and the
'+hadamard' MergeQuant variants.

Two rotations are used (DESIGN.md §2 hardware note):

* **Residual-stream rotation** — a dense orthogonal Q folded *offline*
  into embedding / in-proj / out-proj / head weights. Valid because
  RMSNorm is rotation-invariant once its γ is folded into the following
  linear (the standard QuaRot trick). Zero runtime cost.
* **Online block-Hadamard** — normalised Walsh–Hadamard with block size
  64 applied to a linear's *input* at runtime (and its transpose folded
  into the weight offline). Works for any d divisible by 64, which every
  model in the zoo satisfies; on CUDA this is QuaRot's fused Hadamard
  kernel, on TPU a small VMEM-resident pass.
"""

from __future__ import annotations

import numpy as np

BLOCK = 64


def random_orthogonal(d: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(d, d))
    q, r = np.linalg.qr(a)
    return (q * np.sign(np.diag(r))).astype(np.float32)


def random_hadamard_like(d: int, seed: int) -> np.ndarray:
    """Randomised Hadamard: H · diag(±1), the QuaRot construction.

    Requires d divisible by BLOCK; uses the block-diagonal FWHT matrix.
    """
    rng = np.random.default_rng(seed)
    signs = rng.choice([-1.0, 1.0], size=d).astype(np.float32)
    h = hadamard_matrix(d)
    return h * signs[None, :]


def hadamard_matrix(d: int) -> np.ndarray:
    """Dense matrix of the block-FWHT(64) transform (for folding/tests)."""
    assert d % BLOCK == 0, d
    h1 = np.array([[1.0]])
    h = h1
    while h.shape[0] < BLOCK:
        h = np.block([[h, h], [h, -h]])
    h = h / np.sqrt(BLOCK)
    full = np.zeros((d, d), dtype=np.float32)
    for b in range(d // BLOCK):
        s = b * BLOCK
        full[s:s + BLOCK, s:s + BLOCK] = h
    return full


def fwht_block64(x: np.ndarray) -> np.ndarray:
    """Apply the normalised block-FWHT(64) along the last axis.

    Matches kernels/ref.py::hadamard_block64_ref and the Rust
    quant::hadamard implementation exactly (same butterfly order).
    """
    d = x.shape[-1]
    assert d % BLOCK == 0, d
    shape = x.shape
    x = x.reshape(-1, d // BLOCK, BLOCK).copy()
    h = 1
    while h < BLOCK:
        nb = BLOCK // (2 * h)
        x = x.reshape(x.shape[0], x.shape[1], nb, 2, h)
        a = x[..., 0, :].copy()
        b = x[..., 1, :].copy()
        x[..., 0, :] = a + b
        x[..., 1, :] = a - b
        x = x.reshape(x.shape[0], shape[-1] // BLOCK, BLOCK)
        h *= 2
    return (x / np.sqrt(BLOCK)).reshape(shape)


def fold_online_hadamard_into_weight(w: np.ndarray) -> np.ndarray:
    """Given y = (x H) @ W', choose W' = Hᵀ W so y = x @ W.

    Block-FWHT is symmetric (H = Hᵀ), so folding = applying the transform
    to each weight column, i.e. along the input axis.
    """
    return fwht_block64(w.T).T.astype(np.float32)


def fold_residual_rotation(params: dict, q: np.ndarray) -> dict:
    """Fold a residual-stream rotation Q into model weights (offline).

    Precondition: norm γ vectors have already been folded into the
    following linears (see baselines.fold_norms), so every norm is
    all-ones and commutes with Q.
    """
    out = {
        "embed": params["embed"] @ q,
        "outlier_gain": np.ones_like(params["outlier_gain"]),
        "final_norm": params["final_norm"].copy(),
        "lm_head": q.T @ params["lm_head"],
        "layers": [],
    }
    for layer in params["layers"]:
        out["layers"].append({
            "attn_norm": layer["attn_norm"].copy(),
            "wq": q.T @ layer["wq"],
            "wk": q.T @ layer["wk"],
            "wv": q.T @ layer["wv"],
            "wo": layer["wo"] @ q,
            "ffn_norm": layer["ffn_norm"].copy(),
            "w_gate": q.T @ layer["w_gate"],
            "w_up": q.T @ layer["w_up"],
            "w_down": layer["w_down"] @ q,
        })
    return out
