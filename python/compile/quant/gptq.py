"""GPTQ weight quantization (Frantar et al. 2022) — the paper's standard
per-channel weight quantizer (§5 "Quantization settings").

Standard formulation: quantize W (n, j) row by row along the input
dimension with error feedback, using the Cholesky factor of the damped
inverse Hessian H = X^T X from calibration inputs. Supports symmetric /
asymmetric and grouped scales so Table 5's W3 variants reuse it.
"""

from __future__ import annotations

import numpy as np

from .quantizer import QWeight, qmax_for_bits, round_half_away


def _solve_hinv_chol(h: np.ndarray, damp_frac: float = 0.01) -> np.ndarray:
    """Upper Cholesky factor of H^{-1} with GPTQ's percdamp damping."""
    n = h.shape[0]
    damp = damp_frac * float(np.mean(np.diag(h))) + 1e-8
    h = h + damp * np.eye(n, dtype=h.dtype)
    hinv = np.linalg.inv(h)
    # Symmetrize for numerical safety before Cholesky.
    hinv = (hinv + hinv.T) / 2
    try:
        return np.linalg.cholesky(hinv).T
    except np.linalg.LinAlgError:
        # Escalate damping until SPD.
        for mult in (10.0, 100.0, 1000.0):
            h2 = h + mult * damp * np.eye(n, dtype=h.dtype)
            hinv = np.linalg.inv(h2)
            hinv = (hinv + hinv.T) / 2
            try:
                return np.linalg.cholesky(hinv).T
            except np.linalg.LinAlgError:
                continue
        raise


def _scales_for(w: np.ndarray, bits: int, sym: bool, group: int):
    """Pre-compute (scale, zero) per (group, column) exactly like RTN."""
    n, j = w.shape
    g = group or n
    wg = w.reshape(n // g, g, j)
    if sym:
        qm = qmax_for_bits(bits)
        scale = np.maximum(np.max(np.abs(wg), axis=1) / qm, 1e-8)
        zero = np.zeros_like(scale)
        lo, hi = -qm, qm
    else:
        lo_v = np.minimum(wg.min(axis=1), 0.0)
        hi_v = np.maximum(wg.max(axis=1), 0.0)
        qrange = 2**bits - 1
        scale = np.maximum((hi_v - lo_v) / qrange, 1e-8)
        zero = round_half_away(-lo_v / scale)
        lo, hi = 0, qrange
    return scale, zero, lo, hi


class GptqContext:
    """Precomputed Hessian Cholesky factor for one set of calibration
    inputs — reusable across the q/k/v (or gate/up) fan-out and across
    LoRA-compensation rounds, which all share X."""

    def __init__(self, x_samples: np.ndarray, damp_frac: float = 0.01):
        h = x_samples.T.astype(np.float64) @ x_samples.astype(np.float64)
        self.dead = np.diag(h) == 0
        h[self.dead, self.dead] = 1.0
        self.hinv_u = _solve_hinv_chol(h, damp_frac)


def gptq_quantize(w: np.ndarray, x_samples: np.ndarray, bits: int = 4,
                  sym: bool = True, group: int = 0,
                  damp_frac: float = 0.01,
                  ctx: GptqContext | None = None) -> QWeight:
    """Quantize W (n, j) given calibration inputs X (S, n).

    Returns a QWeight with the same storage layout as RTN so the engine
    and the dequant path are shared. Pass ``ctx`` to reuse the Hessian
    factorization across multiple weights sharing the same inputs.
    """
    n, j = w.shape
    g = group or n
    if ctx is None:
        ctx = GptqContext(x_samples, damp_frac)
    dead = ctx.dead
    w = w.astype(np.float64).copy()
    w[dead, :] = 0.0
    hinv_u = ctx.hinv_u

    scale, zero, lo, hi = _scales_for(w.astype(np.float32), bits, sym, group)
    wq = np.zeros((n, j), dtype=np.float64)
    for i in range(n):
        gi = i // g
        wi = w[i, :]
        q = np.clip(round_half_away(wi / scale[gi]) + zero[gi], lo, hi)
        wq[i, :] = q
        dq = (q - zero[gi]) * scale[gi]
        err = (wi - dq) / hinv_u[i, i]
        # Error feedback into the not-yet-quantized rows.
        if i + 1 < n:
            w[i + 1:, :] -= np.outer(hinv_u[i, i + 1:], err)
    zq = None
    if not sym:
        # Shift to signed storage, matching quantizer.quantize_weight.
        shift = 2 ** (bits - 1)
        wq = wq - shift
        zq = (zero - shift).astype(np.int16)
    return QWeight(wq=wq.astype(np.int8), scale=scale.astype(np.float32),
                   zero=zq, group=group, bits=bits)
