"""Core quantization primitives (paper §2, Eq. 1).

Conventions shared with the Rust engine (rust/src/quant):

* rounding is round-half-away-from-zero (``f32::round`` in Rust);
* symmetric b-bit integer range is [-(2^(b-1)-1), 2^(b-1)-1] (no -2^(b-1),
  matching the paper's ``2^{b-1}-1`` denominator);
* asymmetric b-bit range is [0, 2^b - 1] with an integer zero point;
* weight matrices are stored (n, j) = (input dim, output dim); "per-channel
  weight quantization" means one scale per output column j; grouped
  quantization splits the *input* dimension into contiguous groups.
"""

from __future__ import annotations

import dataclasses

import numpy as np


def qmax_for_bits(bits: int) -> int:
    return 2 ** (bits - 1) - 1


def round_half_away(x: np.ndarray) -> np.ndarray:
    return np.sign(x) * np.floor(np.abs(x) + 0.5)


def quantize_sym(x: np.ndarray, scale: np.ndarray, bits: int) -> np.ndarray:
    """Integer values (float array) in [-qmax, qmax]; scale broadcasts."""
    qm = qmax_for_bits(bits)
    return np.clip(round_half_away(x / scale), -qm, qm)


def absmax_scale(x: np.ndarray, axis, bits: int, clip: float = 1.0,
                 keepdims: bool = True) -> np.ndarray:
    qm = qmax_for_bits(bits)
    s = np.max(np.abs(x), axis=axis, keepdims=keepdims) * clip / qm
    return np.maximum(s, 1e-8)


@dataclasses.dataclass
class QWeight:
    """A quantized weight matrix plus everything needed to dequantize.

    wq: int8 (n, j) integer values.
    scale: f32 (G, j) where G = n/group (G=1 for per-column row-wise).
    zero: int8 (G, j) zero points (asymmetric) or None (symmetric).
    group: group size along the input dim (0 ⇒ one group = whole column).
    bits: weight bit width.
    """

    wq: np.ndarray
    scale: np.ndarray
    zero: np.ndarray | None
    group: int
    bits: int

    @property
    def shape(self):
        return self.wq.shape

    def dequant(self) -> np.ndarray:
        n, j = self.wq.shape
        g = self.group or n
        wq = self.wq.astype(np.float32).reshape(n // g, g, j)
        if self.zero is not None:
            wq = wq - self.zero[:, None, :].astype(np.float32)
        w = wq * self.scale[:, None, :]
        return w.reshape(n, j)


def quantize_weight(w: np.ndarray, bits: int = 4, sym: bool = True,
                    group: int = 0, clip: float = 1.0) -> QWeight:
    """RTN weight quantization, per output column, optionally grouped/asym."""
    n, j = w.shape
    g = group or n
    assert n % g == 0, (n, g)
    wg = w.reshape(n // g, g, j)
    if sym:
        qm = qmax_for_bits(bits)
        scale = np.maximum(np.max(np.abs(wg), axis=1) * clip / qm, 1e-8)
        wq = np.clip(round_half_away(wg / scale[:, None, :]), -qm, qm)
        zero = None
    else:
        lo = np.minimum(wg.min(axis=1) * clip, 0.0)
        hi = np.maximum(wg.max(axis=1) * clip, 0.0)
        qrange = 2**bits - 1
        scale = np.maximum((hi - lo) / qrange, 1e-8)
        # Shift to signed storage (wq−zero is shift-invariant) so int8
        # holds any bits ≤ 8; the Rust engine shares this convention.
        shift = 2 ** (bits - 1)
        zero_u = round_half_away(-lo / scale)
        wq = np.clip(round_half_away(wg / scale[:, None, :])
                     + zero_u[:, None, :], 0, qrange) - shift
        zero = (zero_u - shift).astype(np.int16)
    return QWeight(wq=wq.reshape(n, j).astype(np.int8), scale=scale.astype(np.float32),
                   zero=zero, group=group, bits=bits)


def weight_quant_error(w: np.ndarray, qw: QWeight) -> float:
    d = qw.dequant() - w
    return float(np.sum(d * d))


def per_token_dynamic_matmul(x: np.ndarray, qw: QWeight, a_bits: int = 4,
                             clip: float = 1.0) -> np.ndarray:
    """Reference per-token dynamic path (numpy; mirrors engine/dynamic.rs)."""
    qm = qmax_for_bits(a_bits)
    s = absmax_scale(x, axis=-1, bits=a_bits, clip=clip)
    xq = np.clip(round_half_away(x / s), -qm, qm)
    return (xq @ qw.dequant()) * s
