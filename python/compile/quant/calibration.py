"""Offline channel-wise calibration (paper §4.1, Appendix B).

Runs the FP32 model over a small calibration set (mixed synth-wiki +
synth-c4, like the paper's WikiText-2 + C4 mix) and collects, per layer:

* the RMSNorm *outputs* feeding qkv / gate+up — per-channel absmax and
  second moment (the Hessian diagonal of the following linear, ``Σ x_k²``,
  used by dimension reconstruction's importance ranking);
* the inputs of the out- and down-projections (per-token layers);
* raw samples of each, subsampled, for clipping search / GPTQ Hessians.

Everything is numpy; calibration is build-time only.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .. import model as M


@dataclasses.dataclass
class TensorStats:
    """Streaming per-channel statistics + a bounded sample reservoir."""

    absmax: np.ndarray  # (d,)
    sqsum: np.ndarray  # (d,)  Σ x²  (Hessian diagonal)
    count: int
    samples: np.ndarray  # (S, d) subsampled rows

    @staticmethod
    def collect(rows: np.ndarray, max_samples: int = 2048) -> "TensorStats":
        rows = rows.reshape(-1, rows.shape[-1]).astype(np.float32)
        take = min(len(rows), max_samples)
        idx = np.linspace(0, len(rows) - 1, take).astype(int)
        return TensorStats(
            absmax=np.max(np.abs(rows), axis=0),
            sqsum=np.sum(rows * rows, axis=0),
            count=len(rows),
            samples=rows[idx],
        )

    def merge(self, other: "TensorStats") -> "TensorStats":
        samples = np.concatenate([self.samples, other.samples])
        if len(samples) > 4096:
            idx = np.linspace(0, len(samples) - 1, 4096).astype(int)
            samples = samples[idx]
        return TensorStats(
            absmax=np.maximum(self.absmax, other.absmax),
            sqsum=self.sqsum + other.sqsum,
            count=self.count + other.count,
            samples=samples,
        )


@dataclasses.dataclass
class LayerCalib:
    attn_norm_out: TensorStats  # input to q/k/v (post-γ RMSNorm output)
    ffn_norm_out: TensorStats  # input to gate/up
    o_in: TensorStats  # input to out-projection
    down_in: TensorStats  # input to down-projection
    # Post-RoPE Q/K and V — exactly what the engine writes to (K, V) or
    # scores against (Q); feeds the static INT8 KV-cache scales.
    q_rope: TensorStats | None = None
    k_rope: TensorStats | None = None
    v_out: TensorStats | None = None


@dataclasses.dataclass
class Calibration:
    layers: list[LayerCalib]
    final_norm_in: TensorStats


def forward_with_capture(cfg: M.ModelConfig, params, tokens: jax.Array):
    """FP32 forward that also returns the activations calibration needs."""
    captures = []
    x = params["embed"][tokens] * params["outlier_gain"]
    cos, sin = M.rope_angles(cfg, jnp.arange(tokens.shape[1]))
    B, T, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    for layer in params["layers"]:
        cap = {}
        h = M.rmsnorm(x, layer["attn_norm"])
        cap["attn_norm_out"] = h
        q = (h @ layer["wq"]).reshape(B, T, H, hd)
        k = (h @ layer["wk"]).reshape(B, T, H, hd)
        v = (h @ layer["wv"]).reshape(B, T, H, hd)
        q, k = M.apply_rope(q, cos, sin), M.apply_rope(k, cos, sin)
        # Post-RoPE Q/K and V, flattened back to (B, T, d) — the KV-cache
        # quantizer calibrates on these (channel layout matches the
        # engine's cache rows).
        cap["q_rope"] = q.reshape(B, T, d)
        cap["k_rope"] = k.reshape(B, T, d)
        cap["v_out"] = v.reshape(B, T, d)
        attn = M.attention(q, k, v).reshape(B, T, d)
        cap["o_in"] = attn
        x = x + attn @ layer["wo"]
        h = M.rmsnorm(x, layer["ffn_norm"])
        cap["ffn_norm_out"] = h
        ff = jax.nn.silu(h @ layer["w_gate"]) * (h @ layer["w_up"])
        cap["down_in"] = ff
        x = x + ff @ layer["w_down"]
        captures.append(cap)
    final_in = x
    x = M.rmsnorm(x, params["final_norm"])
    logits = x @ params["lm_head"]
    return logits, captures, final_in


def calibrate(cfg: M.ModelConfig, params, batches: list[np.ndarray],
              max_samples: int = 2048) -> Calibration:
    """batches: list of (B, T) int32 token arrays."""
    params = jax.tree.map(jnp.asarray, params)
    fwd = jax.jit(lambda t: forward_with_capture(cfg, params, t))
    acc: list[LayerCalib] | None = None
    final_stats: TensorStats | None = None
    for toks in batches:
        _, captures, final_in = fwd(jnp.asarray(toks))
        layer_stats = [
            LayerCalib(
                attn_norm_out=TensorStats.collect(np.asarray(c["attn_norm_out"]), max_samples),
                ffn_norm_out=TensorStats.collect(np.asarray(c["ffn_norm_out"]), max_samples),
                o_in=TensorStats.collect(np.asarray(c["o_in"]), max_samples),
                down_in=TensorStats.collect(np.asarray(c["down_in"]), max_samples),
                q_rope=TensorStats.collect(np.asarray(c["q_rope"]), max_samples),
                k_rope=TensorStats.collect(np.asarray(c["k_rope"]), max_samples),
                v_out=TensorStats.collect(np.asarray(c["v_out"]), max_samples),
            )
            for c in captures
        ]
        fstats = TensorStats.collect(np.asarray(final_in), max_samples)
        if acc is None:
            acc, final_stats = layer_stats, fstats
        else:
            acc = [
                LayerCalib(
                    attn_norm_out=a.attn_norm_out.merge(b.attn_norm_out),
                    ffn_norm_out=a.ffn_norm_out.merge(b.ffn_norm_out),
                    o_in=a.o_in.merge(b.o_in),
                    down_in=a.down_in.merge(b.down_in),
                    q_rope=a.q_rope.merge(b.q_rope),
                    k_rope=a.k_rope.merge(b.k_rope),
                    v_out=a.v_out.merge(b.v_out),
                )
                for a, b in zip(acc, layer_stats)
            ]
            final_stats = final_stats.merge(fstats)
    assert acc is not None
    return Calibration(layers=acc, final_norm_in=final_stats)


def kv_scales_from_calib(cfg: M.ModelConfig, calib: Calibration,
                         qmax: int = 127) -> list[dict]:
    """Static INT8 KV-cache scales (engine `quant/kv.rs`, DESIGN.md §10).

    Per layer: per-channel ``k_scale``/``v_scale`` from the post-RoPE K/V
    absmax, and a per-head ``qk_scale`` = max_{c∈h}(q_absmax_c·k_scale_c)
    / qmax so the engine can quantize Q with the K channel scales folded
    in and rescale QK^T scores by one scalar per head.
    """
    hd = cfg.head_dim
    out = []
    for lc in calib.layers:
        if lc.k_rope is None or lc.q_rope is None or lc.v_out is None:
            raise ValueError("calibration lacks post-RoPE q/k/v captures")
        k_scale = np.maximum(lc.k_rope.absmax, 1e-6) / qmax
        v_scale = np.maximum(lc.v_out.absmax, 1e-6) / qmax
        qk = (lc.q_rope.absmax * k_scale).reshape(cfg.n_heads, hd)
        qk_scale = np.maximum(qk.max(axis=1), 1e-12) / qmax
        out.append({"k_scale": k_scale.astype(np.float32),
                    "v_scale": v_scale.astype(np.float32),
                    "qk_scale": qk_scale.astype(np.float32)})
    return out


def channel_absmax_report(calib: Calibration) -> dict:
    """Per-layer channel absmax vectors (Figures 5/6 data)."""
    return {
        f"layer{i}.{name}": getattr(lc, name).absmax.tolist()
        for i, lc in enumerate(calib.layers)
        for name in ("attn_norm_out", "ffn_norm_out", "o_in", "down_in")
    }
