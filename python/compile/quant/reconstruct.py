"""Dimension reconstruction (paper §4.2).

DeQuant migration folds the per-channel activation scale s_k into the
weight rows. Channels whose s_k is far above the rest ("strong
parameters", s_k > T = μ + α·σ) would dominate the per-column weight
quantization after folding. We:

1. split every strong scale s_k into (s_k − mT, T, …, T) — the quantized
   activation value xq_k is *duplicated* into the extra positions at
   runtime via a single gather (``recon_idx``), so each folded weight row
   carries a bounded factor ≤ T;
2. restore the original dimension by pruning an equal number M of
   unimportant channels — preferring *neighbors* of outlier channels
   (Guo et al. 2023: channels adjacent to outliers carry little
   information) ranked by the Hessian diagonal Σ x_k², with the paper's
   three neighbor cases handled explicitly.

The output is a permutation-with-duplicates index vector (d,), the folded
per-position scale (d,), and bookkeeping for tests/reports.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Reconstruction:
    recon_idx: np.ndarray  # i32 (d,): reconstructed position -> source channel
    fold_scale: np.ndarray  # f32 (d,): σ factor folded into that weight row
    threshold: float
    strong: np.ndarray  # indices of strong channels
    pruned: np.ndarray  # indices of pruned channels
    n_split_extra: int  # M

    def apply_to_weight(self, w: np.ndarray) -> np.ndarray:
        """Folded weight W'_ij = σ_i · W[src_i, j] (offline)."""
        return w[self.recon_idx] * self.fold_scale[:, None]

    def apply_to_activation(self, xq: np.ndarray) -> np.ndarray:
        """Runtime gather (paper App. C.1 ``Reconstructed_activation_matrix``)."""
        return xq[..., self.recon_idx]


def split_threshold(s: np.ndarray, alpha: float) -> float:
    """T = μ(s) + α·σ(s), Eq. (6)."""
    return float(np.mean(s) + alpha * np.std(s))


def split_strong(s: np.ndarray, threshold: float) -> tuple[list[int], list[list[float]]]:
    """Decompose each strong scale into parts ≤ T: (s−mT, T, ..., T)."""
    strong, parts = [], []
    for k, sk in enumerate(s):
        if sk > threshold:
            strong.append(k)
            m = int(np.ceil(sk / threshold)) - 1
            rem = sk - m * threshold
            parts.append([rem] + [threshold] * m)
    return strong, parts


def neighbor_channels(strong: list[int], d: int) -> list[int]:
    """Neighbors of outlier channels, the paper's three cases:

    (1) adjacent outliers share no duplicate neighbor;
    (2) a single normal channel between two outliers counts once;
    (3) outliers at position 0 / d−1 have only one side.
    """
    strong_set = set(strong)
    seen: set[int] = set()
    out: list[int] = []
    for k in strong:
        for nb in (k - 1, k + 1):
            if 0 <= nb < d and nb not in strong_set and nb not in seen:
                seen.add(nb)
                out.append(nb)
    return out


def choose_pruned(strong: list[int], hessian_diag: np.ndarray, m_needed: int) -> list[int]:
    """Pick M channels to prune (paper's three schemes on N vs M)."""
    d = len(hessian_diag)
    neigh = neighbor_channels(strong, d)
    n = len(neigh)
    if m_needed == 0:
        return []
    if n >= m_needed:
        # Scheme 1/2: least-important M neighbors by Hessian diagonal.
        order = sorted(neigh, key=lambda c: hessian_diag[c])
        return order[:m_needed]
    # Scheme 3: all neighbors + least-important others.
    rest = [c for c in range(d)
            if c not in set(neigh) and c not in set(strong)]
    rest.sort(key=lambda c: hessian_diag[c])
    return neigh + rest[: m_needed - n]


def reconstruct(s: np.ndarray, hessian_diag: np.ndarray,
                alpha: float = 5.0) -> Reconstruction:
    """Build the reconstruction for one calibrated scale vector s (d,)."""
    d = len(s)
    t = split_threshold(s, alpha)
    strong, parts = split_strong(s, t)
    m = sum(len(p) - 1 for p in parts)
    pruned = choose_pruned(strong, hessian_diag, m)
    pruned_set = set(pruned)
    assert len(pruned) == m, (len(pruned), m)

    recon_idx: list[int] = []
    fold_scale: list[float] = []
    strong_parts = dict(zip(strong, parts))
    for k in range(d):
        if k in pruned_set:
            continue
        if k in strong_parts:
            for sigma in strong_parts[k]:
                recon_idx.append(k)
                fold_scale.append(sigma)
        else:
            recon_idx.append(k)
            fold_scale.append(float(s[k]))
    assert len(recon_idx) == d, (len(recon_idx), d)
    return Reconstruction(
        recon_idx=np.asarray(recon_idx, dtype=np.int32),
        fold_scale=np.asarray(fold_scale, dtype=np.float32),
        threshold=t,
        strong=np.asarray(strong, dtype=np.int32),
        pruned=np.asarray(sorted(pruned), dtype=np.int32),
        n_split_extra=m,
    )


def identity_reconstruction(s: np.ndarray) -> Reconstruction:
    """No-op reconstruction (used by the '+QSM only' ablation row)."""
    d = len(s)
    return Reconstruction(
        recon_idx=np.arange(d, dtype=np.int32),
        fold_scale=np.asarray(s, dtype=np.float32),
        threshold=float("inf"),
        strong=np.empty(0, dtype=np.int32),
        pruned=np.empty(0, dtype=np.int32),
        n_split_extra=0,
    )
