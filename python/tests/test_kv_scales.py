"""Static INT8 KV-cache scale calibration (DESIGN.md §10).

Validates the scale algebra the Rust engine (`quant/kv.rs`) relies on:

* shapes / positivity of the calibrated per-channel and per-head scales;
* the fold — quantizing Q with the K channel scales divided by the
  per-head ``qk_scale`` makes the i8×i8 score dot recover Q·Kᵀ up to one
  scalar (``qk_scale[h]``), i.e. per-channel factors really cancel;
* attention context error vs f32 attention stays small on calibrated
  activations;
* `.qmod` round-trip of the kv section (format 2).
"""

import numpy as np
import pytest

from compile.quant import calibration as C


@pytest.fixture(scope="module")
def kv_scales(small_cfg, small_calib):
    return C.kv_scales_from_calib(small_cfg, small_calib)


def test_kv_scale_shapes_and_positivity(small_cfg, kv_scales):
    assert len(kv_scales) == small_cfg.n_layers
    for sc in kv_scales:
        assert sc["k_scale"].shape == (small_cfg.d_model,)
        assert sc["v_scale"].shape == (small_cfg.d_model,)
        assert sc["qk_scale"].shape == (small_cfg.n_heads,)
        for v in sc.values():
            assert np.all(v > 0) and np.all(np.isfinite(v))


def _round_half_away(x):
    return np.sign(x) * np.floor(np.abs(x) + 0.5)


def _quant(x, mult, qmax=127):
    return np.clip(_round_half_away(x * mult), -qmax, qmax).astype(np.int32)


def test_score_fold_recovers_qk_dot(small_cfg, small_calib, kv_scales):
    # On calibration samples: dot(q_hat, k_hat) * qk_scale[h] ≈ q·k.
    hd = small_cfg.head_dim
    lc = small_calib.layers[0]
    sc = kv_scales[0]
    q = lc.q_rope.samples[:64]
    k = lc.k_rope.samples[:64]
    k_inv = 1.0 / sc["k_scale"]
    for h in range(small_cfg.n_heads):
        lo, hi = h * hd, (h + 1) * hd
        q_mult = sc["k_scale"][lo:hi] / sc["qk_scale"][h]
        qh = _quant(q[:, lo:hi], q_mult)
        kh = _quant(k[:, lo:hi], k_inv[lo:hi])
        got = (qh @ kh.T).astype(np.float64) * sc["qk_scale"][h]
        want = q[:, lo:hi].astype(np.float64) @ k[:, lo:hi].T.astype(np.float64)
        scale = np.abs(want).max() + 1e-9
        err = np.abs(got - want).max()
        assert err <= 0.03 * scale, f"head {h}: {err} vs scale {scale}"


def test_int8_attention_context_close_to_f32(small_cfg, small_calib,
                                             kv_scales):
    # Full attention (scores → softmax → prob×V) in the integer domain vs
    # f32, on calibrated activations of layer 0.
    hd = small_cfg.head_dim
    lc = small_calib.layers[0]
    sc = kv_scales[0]
    q = lc.q_rope.samples[:8]
    k = lc.k_rope.samples[:48]
    v = lc.v_out.samples[:48]
    inv_sqrt = 1.0 / np.sqrt(hd)
    for h in range(small_cfg.n_heads):
        lo, hi = h * hd, (h + 1) * hd
        # f32 reference
        s_f = (q[:, lo:hi] @ k[:, lo:hi].T) * inv_sqrt
        p_f = np.exp(s_f - s_f.max(axis=1, keepdims=True))
        p_f /= p_f.sum(axis=1, keepdims=True)
        ctx_f = p_f @ v[:, lo:hi]
        # integer path
        q_mult = sc["k_scale"][lo:hi] / sc["qk_scale"][h]
        qh = _quant(q[:, lo:hi], q_mult)
        kh = _quant(k[:, lo:hi], 1.0 / sc["k_scale"][lo:hi])
        vh = _quant(v[:, lo:hi], 1.0 / sc["v_scale"][lo:hi])
        s_i = (qh @ kh.T) * sc["qk_scale"][h] * inv_sqrt
        p_i = np.exp(s_i - s_i.max(axis=1, keepdims=True))
        p_i /= p_i.sum(axis=1, keepdims=True)
        ctx_i = (p_i @ vh) * sc["v_scale"][lo:hi]
        scale = np.abs(ctx_f).max() + 1e-9
        err = np.abs(ctx_i - ctx_f).max()
        assert err <= 0.05 * scale, f"head {h}: {err} vs {scale}"


def test_kv_roundtrip_error_half_scale(small_calib, kv_scales):
    lc = small_calib.layers[0]
    sc = kv_scales[0]
    k = np.clip(lc.k_rope.samples[:128], -127 * sc["k_scale"],
                127 * sc["k_scale"])
    kq = _quant(k, 1.0 / sc["k_scale"])
    back = kq * sc["k_scale"]
    assert np.all(np.abs(k - back) <= sc["k_scale"] / 2 + 1e-6)


def test_qmod_carries_kv_section(tmp_path, small_cfg, small_params,
                                 small_batches, small_calib):
    from compile.qmod import load_qmod, save_qmod
    from compile.quant.pipeline import mergequant

    qm = mergequant(small_cfg, small_params, small_batches,
                    lora_rank=0, use_gptq=False, calib=small_calib)
    assert "kv" in qm and len(qm["kv"]) == small_cfg.n_layers
    path = tmp_path / "kv.qmod"
    save_qmod(path, qm)
    back = load_qmod(path)
    assert back["kv"] is not None
    for a, b in zip(qm["kv"], back["kv"]):
        for name in ("k_scale", "v_scale", "qk_scale"):
            np.testing.assert_allclose(a[name], b[name], rtol=0, atol=0)


def test_kv_scales_require_captures(small_cfg, small_calib):
    import dataclasses
    stripped = C.Calibration(
        layers=[dataclasses.replace(lc, q_rope=None)
                for lc in small_calib.layers],
        final_norm_in=small_calib.final_norm_in,
    )
    with pytest.raises(ValueError):
        C.kv_scales_from_calib(small_cfg, stripped)
