"""Quantization Step Migration exactness (paper §4.1, Eq. 4–5).

The central claim: merging γ/s into the norm multiplier and folding s into
the weight rows changes *nothing* about the computed output (before weight
quantization). These tests verify both migrations exactly.
"""

import numpy as np
import jax.numpy as jnp

from compile import model as M
from compile.kernels import ref as R

RNG = np.random.default_rng(7)


def test_quant_migration_exact():
    """round(RMSNorm(x)/s) == round(x/RMS(x) · (γ/s))  (Eq. 4)."""
    d = 96
    x = RNG.normal(size=(32, d)).astype(np.float32) * 3
    gamma = RNG.uniform(0.2, 2.0, size=d).astype(np.float32)
    s = RNG.uniform(0.05, 0.5, size=d).astype(np.float32)
    # unmerged: normalize with gamma, then divide by s, then round
    normed = np.asarray(M.rmsnorm(jnp.asarray(x), jnp.asarray(gamma)))
    lhs = np.clip(np.sign(normed / s) * np.floor(np.abs(normed / s) + 0.5),
                  -7, 7)
    # merged: multiplier already holds gamma/s
    rhs = np.asarray(R.rmsnorm_quant_ref(jnp.asarray(x),
                                         jnp.asarray(gamma / s), 7))
    np.testing.assert_array_equal(lhs, rhs)


def test_dequant_migration_exact():
    """Σ_k s_k xq_k W_kj == Σ_k xq_k (s_k W_kj)  (Eq. 5), exactly."""
    n, j = 64, 48
    xq = RNG.integers(-7, 8, size=(16, n)).astype(np.float32)
    s = RNG.uniform(0.05, 0.5, size=n).astype(np.float32)
    w = RNG.normal(size=(n, j)).astype(np.float32)
    inside = (xq * s) @ w  # scale stuck inside the accumulation (Eq. 3)
    migrated = xq @ (s[:, None] * w)  # scale folded into the weight
    np.testing.assert_allclose(inside, migrated, rtol=1e-5, atol=1e-5)


def test_qsm_end_to_end_matches_fakequant():
    """Full static path == textbook per-channel fake-quant linear layer."""
    d, j = 64, 32
    x = RNG.normal(size=(24, d)).astype(np.float32) * 2
    x[:, 5] *= 12  # outlier channel
    gamma = RNG.uniform(0.5, 1.5, size=d).astype(np.float32)
    w = RNG.normal(size=(d, j)).astype(np.float32)

    normed = np.asarray(M.rmsnorm(jnp.asarray(x), jnp.asarray(gamma)))
    s = np.abs(normed).max(axis=0) / 7  # per-channel calibration

    # textbook: fake-quantize activations, then FP matmul
    xq = np.clip(np.sign(normed / s) * np.floor(np.abs(normed / s) + 0.5),
                 -7, 7)
    want = (xq * s) @ w

    # QSM: merged norm emits integers, weight carries s (no weight quant yet)
    xq_merged = np.asarray(R.rmsnorm_quant_ref(jnp.asarray(x),
                                               jnp.asarray(gamma / s), 7))
    got = xq_merged @ (s[:, None] * w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_layernorm_migration_variant():
    """LayerNorm case: both γ/s and β/s merge (paper §4.1)."""
    d = 64
    x = RNG.normal(size=(16, d)).astype(np.float32)
    gamma = RNG.uniform(0.5, 1.5, size=d).astype(np.float32)
    beta = RNG.normal(size=d).astype(np.float32) * 0.1
    s = RNG.uniform(0.05, 0.2, size=d).astype(np.float32)

    mu = x.mean(-1, keepdims=True)
    sd = x.std(-1, keepdims=True) + 1e-5
    ln = (x - mu) / sd * gamma + beta
    lhs = np.round(ln / s)

    merged = (x - mu) / sd * (gamma / s) + beta / s
    rhs = np.round(merged)
    np.testing.assert_allclose(lhs, rhs, atol=1.0)  # ties may differ by 1
    assert np.mean(lhs != rhs) < 0.01
