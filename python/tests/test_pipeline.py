"""Pipeline-level tests: method registry, QSM model structure, orderings,
qmod roundtrip, Pallas-path equivalence."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import qmod as QM
from compile.quant import pipeline as P
from compile.quant import baselines as B
from compile.quant.qforward import quant_forward, fp_quant_model

RNG = np.random.default_rng(11)


@pytest.fixture(scope="module")
def mq_model(small_cfg, small_params, small_batches, small_calib):
    return P.mergequant(small_cfg, small_params, small_batches,
                        calib=small_calib, lora_rank=4)


def _logit_err(cfg, params, qm, toks):
    ref = M.forward(cfg, params, jnp.asarray(toks))
    got = quant_forward(cfg, qm, jnp.asarray(toks))
    return float(jnp.mean(jnp.abs(got - ref)))


def test_mergequant_structure(small_cfg, mq_model):
    layer = mq_model["layers"][0]
    assert layer["attn_norm"]["quant"] is not None
    assert layer["attn_norm"]["quant"]["qmax"] == 7
    for name in ("q", "k", "v", "gate", "up"):
        assert layer[name]["mode"] == "static"
        assert layer[name]["qw"].wq.dtype == np.int8
    for name in ("o", "down"):
        assert layer[name]["mode"] == "dynamic"
        assert layer[name]["hadamard"]  # default variant uses the rotation
        assert 0.5 <= layer[name]["a_clip"] <= 1.0


@pytest.fixture(scope="module")
def mq_static_model(small_cfg, small_params, small_batches, small_calib):
    return P.build_method("mergequant_static", small_cfg, small_params,
                          small_batches, calib=small_calib)


def test_mergequant_static_structure(mq_static_model):
    """End-to-end static W4A4: o/down carry channel_static specs with
    per-channel scales (and the compiled model is named accordingly)."""
    assert mq_static_model["method"] == "mergequant_static"
    layer = mq_static_model["layers"][0]
    for name in ("q", "k", "v", "gate", "up"):
        assert layer[name]["mode"] == "static"
    for name in ("o", "down"):
        spec = layer[name]
        assert spec["mode"] == "channel_static"
        n = spec["qw"].wq.shape[0]
        assert spec["a_scale"].shape == (n,)
        assert (spec["a_scale"] > 0).all()
        assert spec["a_qmax"] == 7
        if spec["recon_idx"] is not None:
            idx = np.asarray(spec["recon_idx"])
            assert idx.shape == (n,)
            assert idx.min() >= 0 and idx.max() < n


def test_mergequant_static_runs_close_to_dynamic(small_cfg, small_params,
                                                 mq_model, mq_static_model):
    """The static o/down path must stay in the same accuracy band as the
    per-token dynamic default it replaces (Table 6 trade: overhead for
    at-worst-modest error growth)."""
    toks = RNG.integers(3, 128, size=(2, 32)).astype(np.int32)
    e_dyn = _logit_err(small_cfg, small_params, mq_model, toks)
    e_static = _logit_err(small_cfg, small_params, mq_static_model, toks)
    assert np.isfinite(e_static)
    assert e_static < max(e_dyn * 3.0, 1.0)


def test_qmod_roundtrip_channel_static(tmp_path, small_cfg,
                                       mq_static_model):
    """channel_static specs survive the .qmod bundle (format 3) bitwise."""
    import json
    path = tmp_path / "ms.qmod"
    QM.save_qmod(path, mq_static_model)
    raw = path.read_bytes()
    mlen = int.from_bytes(raw[len(QM.MAGIC):len(QM.MAGIC) + 4], "little")
    meta = json.loads(raw[len(QM.MAGIC) + 4:len(QM.MAGIC) + 4 + mlen])
    assert meta["format"] == 3
    loaded = QM.load_qmod(path)
    spec0 = mq_static_model["layers"][0]["o"]
    got0 = loaded["layers"][0]["o"]
    assert got0["mode"] == "channel_static"
    np.testing.assert_array_equal(got0["a_scale"], spec0["a_scale"])
    if spec0["recon_idx"] is None:
        assert got0["recon_idx"] is None
    else:
        np.testing.assert_array_equal(got0["recon_idx"], spec0["recon_idx"])
    toks = RNG.integers(3, 128, size=(1, 16)).astype(np.int32)
    a = quant_forward(small_cfg, mq_static_model, jnp.asarray(toks))
    b = quant_forward(small_cfg, loaded, jnp.asarray(toks))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_merged_multiplier_holds_gamma_over_s(small_cfg, small_params,
                                              small_calib, small_batches):
    """g_merged · s == γ  (quant migration bookkeeping)."""
    qm = P.mergequant(small_cfg, small_params, small_batches,
                      calib=small_calib, clipping="none", lora_rank=0,
                      do_reconstruct=False)
    qa = 7
    stats = small_calib.layers[0].attn_norm_out
    s = np.maximum(stats.absmax, 1e-6) / qa
    g_merged = qm["layers"][0]["attn_norm"]["g"]
    gamma = np.asarray(small_params["layers"][0]["attn_norm"])
    np.testing.assert_allclose(g_merged * s, gamma, rtol=1e-4)


def test_fp16_wrapper_is_exact(small_cfg, small_params):
    toks = RNG.integers(3, 128, size=(2, 16)).astype(np.int32)
    qm = fp_quant_model(small_cfg, small_params)
    err = _logit_err(small_cfg, small_params, qm, toks)
    assert err < 1e-5


def test_perchannel_beats_pertensor_static(small_cfg, small_params,
                                           small_batches, small_calib):
    """Fig 1's core claim on the outlier model."""
    toks = RNG.integers(3, 128, size=(2, 32)).astype(np.int32)
    e_channel = _logit_err(small_cfg, small_params,
                           P.build_method("perchannel_static", small_cfg,
                                          small_params, small_batches,
                                          calib=small_calib), toks)
    e_tensor = _logit_err(small_cfg, small_params,
                          P.build_method("pertensor_static", small_cfg,
                                         small_params, small_batches,
                                         calib=small_calib), toks)
    assert e_channel < e_tensor


def test_ablation_monotone(small_cfg, small_params, small_batches,
                           small_calib, mq_model):
    """Table 4 shape: +clipping and +LoRA do not hurt vs QSM-only."""
    toks = RNG.integers(3, 128, size=(4, 32)).astype(np.int32)
    e_qsm = _logit_err(small_cfg, small_params,
                       P.build_method("mq_qsm_only", small_cfg, small_params,
                                      small_batches, calib=small_calib), toks)
    e_full = _logit_err(small_cfg, small_params, mq_model, toks)
    assert e_full < e_qsm * 1.25  # full pipeline no (much) worse
    assert e_full < 1.0


def test_all_registry_methods_build_and_run(small_cfg, small_params,
                                            small_batches, small_calib):
    toks = RNG.integers(3, 128, size=(1, 16)).astype(np.int32)
    methods = set(P.TABLE1_METHODS + P.TABLE4_METHODS + P.TABLE5_METHODS +
                  P.TABLE7_METHODS + P.FIG1_METHODS)
    for meth in sorted(methods):
        qm = P.build_method(meth, small_cfg, small_params, small_batches,
                            calib=small_calib)
        out = quant_forward(small_cfg, qm, jnp.asarray(toks))
        assert np.isfinite(np.asarray(out)).all(), meth


def test_unknown_method_raises(small_cfg, small_params, small_batches):
    with pytest.raises(ValueError):
        P.build_method("nope", small_cfg, small_params, small_batches)


def test_fold_norms_preserves_forward(small_cfg, small_params):
    toks = RNG.integers(3, 128, size=(2, 16)).astype(np.int32)
    ref = M.forward(small_cfg, small_params, jnp.asarray(toks))
    folded = B.fold_norms(small_params)
    got = M.forward(small_cfg, folded, jnp.asarray(toks))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_residual_rotation_preserves_forward(small_cfg, small_params):
    from compile.quant import hadamard as H
    toks = RNG.integers(3, 128, size=(2, 16)).astype(np.int32)
    ref = M.forward(small_cfg, small_params, jnp.asarray(toks))
    rot = H.fold_residual_rotation(B.fold_norms(small_params),
                                   H.random_hadamard_like(small_cfg.d_model, 1))
    got = M.forward(small_cfg, rot, jnp.asarray(toks))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=5e-2, atol=5e-2)


def test_qmod_roundtrip(tmp_path, small_cfg, mq_model):
    path = tmp_path / "m.qmod"
    QM.save_qmod(path, mq_model)
    loaded = QM.load_qmod(path)
    assert loaded["method"] == mq_model["method"]
    assert loaded["config"].d_model == small_cfg.d_model
    toks = RNG.integers(3, 128, size=(1, 16)).astype(np.int32)
    a = quant_forward(small_cfg, mq_model, jnp.asarray(toks))
    b = quant_forward(small_cfg, loaded, jnp.asarray(toks))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_qmod_roundtrip_fp_and_asym(tmp_path, small_cfg, small_params,
                                    small_batches, small_calib):
    for meth in ("fp16", "mergequant_w3_asym", "mergequant_w3_group"):
        qm = P.build_method(meth, small_cfg, small_params, small_batches,
                            calib=small_calib)
        path = tmp_path / f"{meth}.qmod"
        QM.save_qmod(path, qm)
        loaded = QM.load_qmod(path)
        toks = RNG.integers(3, 128, size=(1, 8)).astype(np.int32)
        a = quant_forward(small_cfg, qm, jnp.asarray(toks))
        b = quant_forward(small_cfg, loaded, jnp.asarray(toks))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_pallas_path_matches_ref_path(small_cfg, mq_model):
    toks = RNG.integers(3, 128, size=(2, 16)).astype(np.int32)
    a = quant_forward(small_cfg, mq_model, jnp.asarray(toks),
                      use_pallas=False)
    b = quant_forward(small_cfg, mq_model, jnp.asarray(toks),
                      use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)


def test_quant_decode_matches_quant_prefill(small_cfg, mq_model):
    from compile.quant.qforward import quant_decode_step
    import jax
    T = 8
    toks = RNG.integers(3, 128, size=(1, T)).astype(np.int32)
    full = np.asarray(quant_forward(small_cfg, mq_model, jnp.asarray(toks)))
    k, v = M.init_cache(small_cfg, 1, T)
    step = jax.jit(lambda t, p, kk, vv: quant_decode_step(
        small_cfg, mq_model, t, p, kk, vv))
    for pos in range(T):
        logits, k, v = step(jnp.asarray(toks[:, pos]), jnp.int32(pos), k, v)
        np.testing.assert_allclose(np.asarray(logits)[0], full[0, pos],
                                   rtol=3e-3, atol=3e-3)
