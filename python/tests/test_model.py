"""Model-level tests: shapes, decode/prefill parity, outlier structure."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import data as D

RNG = np.random.default_rng(3)


def test_forward_shapes(small_cfg, small_params):
    toks = RNG.integers(3, small_cfg.vocab, size=(2, 16)).astype(np.int32)
    logits = M.forward(small_cfg, small_params, jnp.asarray(toks))
    assert logits.shape == (2, 16, small_cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_param_count_matches_zoo():
    for cfg in M.MODEL_ZOO.values():
        assert 0.5e6 < cfg.param_count() < 20e6


def test_decode_matches_prefill(small_cfg, small_params):
    """Step-by-step decode logits == full prefill logits at each position."""
    T = 12
    toks = RNG.integers(3, small_cfg.vocab, size=(1, T)).astype(np.int32)
    full = np.asarray(M.forward(small_cfg, small_params, jnp.asarray(toks)))

    k, v = M.init_cache(small_cfg, 1, T)
    step = jax.jit(lambda t, p, kk, vv: M.decode_step(
        small_cfg, small_params, t, p, kk, vv))
    for pos in range(T):
        logits, k, v = step(jnp.asarray(toks[:, pos]), jnp.int32(pos), k, v)
        np.testing.assert_allclose(np.asarray(logits)[0], full[0, pos],
                                   rtol=2e-3, atol=2e-3)


def test_causality(small_cfg, small_params):
    """Changing a future token must not change past logits."""
    toks = RNG.integers(3, small_cfg.vocab, size=(1, 16)).astype(np.int32)
    a = np.asarray(M.forward(small_cfg, small_params, jnp.asarray(toks)))
    toks2 = toks.copy()
    toks2[0, -1] = (toks2[0, -1] + 1) % small_cfg.vocab
    b = np.asarray(M.forward(small_cfg, small_params, jnp.asarray(toks2)))
    np.testing.assert_allclose(a[0, :-1], b[0, :-1], rtol=1e-5, atol=1e-5)


def test_outlier_channels_are_structured(small_cfg, small_params):
    """The induced outlier channels dominate the residual-stream absmax."""
    from compile.quant import calibration as C
    batches = [RNG.integers(3, small_cfg.vocab, size=(2, 32)).astype(np.int32)]
    calib = C.calibrate(small_cfg, small_params, batches)
    am = calib.layers[0].attn_norm_out.absmax
    outliers = [c % small_cfg.d_model for c in small_cfg.outlier_channels]
    normal = [i for i in range(small_cfg.d_model) if i not in outliers]
    assert am[outliers].min() > 2.5 * np.median(am[normal])


def test_rope_rotation_preserves_norm(small_cfg):
    x = RNG.normal(size=(1, 8, small_cfg.n_heads,
                         small_cfg.head_dim)).astype(np.float32)
    cos, sin = M.rope_angles(small_cfg, jnp.arange(8))
    y = np.asarray(M.apply_rope(jnp.asarray(x), cos, sin))
    np.testing.assert_allclose(np.linalg.norm(y, axis=-1),
                               np.linalg.norm(x, axis=-1), rtol=1e-5)


def test_perplexity_of_random_model_near_vocab(small_cfg, small_params):
    toks = D.generate_corpus(D.SYNTH_WIKI, 2100)
    toks = np.clip(toks, 0, small_cfg.vocab - 1)
    ppl = M.perplexity(small_cfg, small_params, toks, seq=64)
    assert 0.3 * small_cfg.vocab < ppl < 3 * small_cfg.vocab


def test_choice_accuracy_random_model_near_chance(small_cfg, small_params):
    items = [{"prefix": RNG.integers(3, 128, 8).tolist(),
              "choices": [RNG.integers(3, 128, 4).tolist() for _ in range(4)],
              "answer": int(RNG.integers(0, 4))} for _ in range(40)]
    acc = M.choice_accuracy(small_cfg, small_params, items)
    assert 0.0 <= acc <= 0.7  # random model, 4 choices


# --------------------------------- data ------------------------------------

def test_corpus_deterministic():
    a = D.generate_corpus(D.SYNTH_WIKI, 5000)
    b = D.generate_corpus(D.SYNTH_WIKI, 5000)
    np.testing.assert_array_equal(a, b)


def test_corpora_differ():
    a = D.generate_corpus(D.SYNTH_WIKI, 5000)
    b = D.generate_corpus(D.SYNTH_C4, 5000)
    assert not np.array_equal(a, b)


def test_batch_iterator_shapes():
    toks = D.generate_corpus(D.SYNTH_WIKI, 10_000)
    it = D.batch_iterator(toks, batch=4, seq=32)
    x, y = next(it)
    assert x.shape == (4, 32) and y.shape == (4, 32)
    np.testing.assert_array_equal(x[:, 1:], y[:, :-1])


@pytest.mark.parametrize("name", D.TASK_NAMES)
def test_tasks_well_formed(name):
    items = D.make_task(name, 20, seed=5)
    n_choices = 2 if name in ("piqa", "winogrande") else 4
    for it in items:
        assert len(it.choices) == n_choices
        assert 0 <= it.answer < n_choices
        assert all(0 <= t < D.VOCAB_SIZE
                   for ch in it.choices for t in ch)
        # the true continuation is present at the answer slot
        assert len(it.choices[it.answer]) in (12, 24)


def test_task_deterministic():
    a = D.make_task("piqa", 10, seed=5)
    b = D.make_task("piqa", 10, seed=5)
    assert all(x.choices == y.choices and x.answer == y.answer
               for x, y in zip(a, b))
