"""Training-loop smoke tests (tiny budget; the real runs happen in aot)."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import model as M
from compile import train as T


def test_adamw_reduces_loss_on_tiny_model():
    cfg = M.ModelConfig("train-smoke", d_model=32, n_heads=2, d_ff=64,
                        n_layers=1, vocab=64, outlier_channels=(3,),
                        outlier_gain=6.0)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    gain = params.pop("outlier_gain")
    rng = np.random.default_rng(0)
    # deterministic mapping task: next token = (t * 3 + 1) % 61 + 3
    x = rng.integers(3, 64, size=(8, 16)).astype(np.int32)
    y = ((x * 3 + 1) % 61 + 3).astype(np.int32)

    def loss(p, xx, yy):
        return M.loss_fn(cfg, {**p, "outlier_gain": gain}, xx, yy)

    grad_fn = jax.jit(jax.value_and_grad(loss))
    opt = T.adamw_init(params)
    first = None
    last = None
    for _ in range(30):
        lval, grads = grad_fn(params, jnp.asarray(x), jnp.asarray(y))
        params, opt = T.adamw_update(params, grads, opt, 5e-3)
        first = first if first is not None else float(lval)
        last = float(lval)
    assert last < first * 0.8, f"{first} -> {last}"


def test_adamw_state_shapes_match():
    cfg = M.ModelConfig("s", d_model=32, n_heads=2, d_ff=64, n_layers=1,
                        vocab=64)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = T.adamw_init(params)
    flat_p = jax.tree.leaves(params)
    flat_m = jax.tree.leaves(opt["m"])
    assert len(flat_p) == len(flat_m)
    for p, m in zip(flat_p, flat_m):
        assert p.shape == m.shape
