import numpy as np
import jax
import pytest

from compile import model as M


@pytest.fixture(scope="session")
def small_cfg():
    return M.ModelConfig("t-small", d_model=64, n_heads=2, d_ff=128,
                         n_layers=2, vocab=128, outlier_channels=(5, 20),
                         outlier_gain=10.0)


@pytest.fixture(scope="session")
def small_params(small_cfg):
    return M.init_params(jax.random.PRNGKey(0), small_cfg)


@pytest.fixture(scope="session")
def small_batches():
    rng = np.random.default_rng(17)
    return [rng.integers(3, 128, size=(2, 32)).astype(np.int32)
            for _ in range(3)]


@pytest.fixture(scope="session")
def small_calib(small_cfg, small_params, small_batches):
    from compile.quant import calibration as C
    return C.calibrate(small_cfg, small_params, small_batches)
