"""L1 Pallas kernels vs the pure-jnp oracle — the core correctness signal.

Randomized shape/value sweeps stand in for hypothesis (not vendored in
this image): every case draws fresh shapes/values from a seeded rng.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import ref as R
from compile.kernels import qsm_matmul as KQ
from compile.kernels import rmsnorm_quant as KN

RNG = np.random.default_rng(0)

SHAPES = [(1, 64, 64), (3, 64, 128), (16, 128, 64), (33, 128, 384),
          (65, 192, 192), (128, 256, 128)]


def _intvals(shape, qmax):
    return RNG.integers(-qmax, qmax + 1, size=shape).astype(np.float32)


@pytest.mark.parametrize("m,n,j", SHAPES)
@pytest.mark.parametrize("qmax", [7, 3])
def test_qsm_matmul_matches_ref(m, n, j, qmax):
    xq = _intvals((m, n), qmax)
    wq = _intvals((n, j), qmax)
    scale = RNG.uniform(1e-3, 0.1, size=j).astype(np.float32)
    got = KQ.qsm_matmul(jnp.asarray(xq), jnp.asarray(wq), jnp.asarray(scale))
    want = R.qsm_matmul_ref(jnp.asarray(xq), jnp.asarray(wq),
                            jnp.asarray(scale))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("m,n,j", SHAPES[:4])
def test_qsm_matmul_asym_matches_ref(m, n, j):
    xq = _intvals((m, n), 7)
    wq = RNG.integers(0, 8, size=(n, j)).astype(np.float32)
    zero = RNG.integers(0, 8, size=j).astype(np.float32)
    scale = RNG.uniform(1e-3, 0.1, size=j).astype(np.float32)
    got = KQ.qsm_matmul_asym(jnp.asarray(xq), jnp.asarray(wq),
                             jnp.asarray(zero), jnp.asarray(scale))
    want = R.qsm_matmul_asym_ref(jnp.asarray(xq), jnp.asarray(wq),
                                 jnp.asarray(zero), jnp.asarray(scale))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("m,n,j", SHAPES[:4])
@pytest.mark.parametrize("clip", [1.0, 0.8])
def test_dyn_quant_matmul_matches_ref(m, n, j, clip):
    x = RNG.normal(size=(m, n)).astype(np.float32) * 3
    wq = _intvals((n, j), 7)
    ws = RNG.uniform(1e-3, 0.1, size=j).astype(np.float32)
    got = KQ.dyn_quant_matmul(jnp.asarray(x), jnp.asarray(wq),
                              jnp.asarray(ws), qmax=7, clip=clip)
    want = R.dyn_quant_matmul_ref(jnp.asarray(x), jnp.asarray(wq),
                                  jnp.asarray(ws), 7, clip)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,d", [(1, 64), (7, 64), (32, 128), (70, 192),
                                 (128, 256)])
@pytest.mark.parametrize("qmax", [7, 3])
def test_rmsnorm_quant_matches_ref(m, d, qmax):
    x = RNG.normal(size=(m, d)).astype(np.float32) * 2
    x[:, 5] *= 20  # outlier channel
    g = RNG.uniform(0.1, 4.0, size=d).astype(np.float32)
    got = KN.rmsnorm_quant(jnp.asarray(x), jnp.asarray(g), qmax=qmax)
    want = R.rmsnorm_quant_ref(jnp.asarray(x), jnp.asarray(g), qmax)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=0)


@pytest.mark.parametrize("m,d", [(5, 64), (32, 128)])
def test_rmsnorm_quant_recon_matches_gather(m, d):
    x = RNG.normal(size=(m, d)).astype(np.float32)
    g = RNG.uniform(0.1, 4.0, size=d).astype(np.float32)
    idx = RNG.integers(0, d, size=d).astype(np.int32)
    got = KN.rmsnorm_quant_recon(jnp.asarray(x), jnp.asarray(g),
                                 jnp.asarray(idx), qmax=7)
    base = R.rmsnorm_quant_ref(jnp.asarray(x), jnp.asarray(g), 7)
    want = np.asarray(base)[:, idx]
    np.testing.assert_array_equal(np.asarray(got), want)


def test_rmsnorm_quant_output_is_integral():
    x = RNG.normal(size=(16, 64)).astype(np.float32)
    g = RNG.uniform(0.1, 4.0, size=64).astype(np.float32)
    out = np.asarray(KN.rmsnorm_quant(jnp.asarray(x), jnp.asarray(g)))
    assert np.all(out == np.round(out))
    assert out.min() >= -7 and out.max() <= 7


def test_round_half_away_semantics():
    x = jnp.asarray([0.5, -0.5, 1.5, -1.5, 2.5, 0.49, -0.49])
    got = np.asarray(R.round_half_away(x))
    np.testing.assert_array_equal(got, [1, -1, 2, -2, 3, 0, -0.0])


@pytest.mark.parametrize("d", [64, 128, 192])
def test_hadamard_ref_orthogonal(d):
    x = RNG.normal(size=(8, d)).astype(np.float32)
    y = R.hadamard_block64_ref(jnp.asarray(x))
    # norm preserved
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=1),
                               np.linalg.norm(x, axis=1), rtol=1e-5)
    # involutive (symmetric orthogonal)
    z = R.hadamard_block64_ref(y)
    np.testing.assert_allclose(np.asarray(z), x, atol=1e-5)


def test_vmem_footprint_fits():
    fp = KQ.vmem_footprint_bytes(2048, 1024, 1024)
    assert fp["fits_16MiB"]
    assert fp["total"] == fp["act"] + fp["weight"] + fp["acc"] + fp["scale"]


@pytest.mark.parametrize("seed", range(5))
def test_qsm_matmul_random_sweep(seed):
    """Property sweep: random small shapes, exactness vs integer math."""
    rng = np.random.default_rng(seed)
    m = int(rng.integers(1, 40))
    n = int(rng.integers(8, 100))
    j = int(rng.integers(8, 100))
    xq = rng.integers(-7, 8, size=(m, n)).astype(np.float32)
    wq = rng.integers(-7, 8, size=(n, j)).astype(np.float32)
    scale = rng.uniform(1e-3, 0.1, size=j).astype(np.float32)
    got = np.asarray(KQ.qsm_matmul(jnp.asarray(xq), jnp.asarray(wq),
                                   jnp.asarray(scale)))
    exact = (xq.astype(np.int64) @ wq.astype(np.int64)).astype(np.float32)
    np.testing.assert_allclose(got, exact * scale, rtol=1e-6)
