"""Unit tests for quantizer / reconstruct / clipping / gptq / lora / hadamard."""

import numpy as np
import pytest

from compile.quant import quantizer as Q
from compile.quant import reconstruct as RC
from compile.quant import clipping as CL
from compile.quant import hadamard as H
from compile.quant.gptq import gptq_quantize
from compile.quant.lora import compensate

RNG = np.random.default_rng(1)


# ----------------------------- quantizer -----------------------------------

def test_qmax_for_bits():
    assert Q.qmax_for_bits(4) == 7
    assert Q.qmax_for_bits(3) == 3
    assert Q.qmax_for_bits(8) == 127


@pytest.mark.parametrize("bits", [3, 4, 8])
@pytest.mark.parametrize("sym", [True, False])
@pytest.mark.parametrize("group", [0, 16])
def test_weight_quant_dequant_error_bounded(bits, sym, group):
    w = RNG.normal(size=(64, 32)).astype(np.float32)
    qw = Q.quantize_weight(w, bits=bits, sym=sym, group=group)
    err = np.abs(qw.dequant() - w)
    # max error per element is half a quantization step of its group/column
    n = w.shape[0]
    g = group or n
    wg = np.abs(w.reshape(n // g, g, 32))
    step = qw.scale
    assert np.all(err.reshape(n // g, g, 32) <= 0.5 * step[:, None, :] + 1e-5)


def test_more_bits_less_error():
    w = RNG.normal(size=(128, 64)).astype(np.float32)
    errs = [Q.weight_quant_error(w, Q.quantize_weight(w, bits=b))
            for b in (2, 3, 4, 8)]
    assert errs == sorted(errs, reverse=True)


def test_grouped_no_worse_than_per_column():
    w = RNG.normal(size=(128, 64)).astype(np.float32)
    w[5, :] *= 30  # one huge input row ruins the whole-column scale
    e_col = Q.weight_quant_error(w, Q.quantize_weight(w, bits=4, group=0))
    e_grp = Q.weight_quant_error(w, Q.quantize_weight(w, bits=4, group=16))
    assert e_grp <= e_col


def test_asym_handles_shifted_weights():
    w = (RNG.normal(size=(64, 32)) + 3.0).astype(np.float32)  # all-positive
    e_sym = Q.weight_quant_error(w, Q.quantize_weight(w, bits=4, sym=True))
    e_asym = Q.weight_quant_error(w, Q.quantize_weight(w, bits=4, sym=False))
    assert e_asym < e_sym


def test_quantize_sym_range():
    x = RNG.normal(size=(100,)).astype(np.float32) * 10
    s = Q.absmax_scale(x, axis=None, bits=4, keepdims=False)
    xq = Q.quantize_sym(x, s, 4)
    assert xq.min() >= -7 and xq.max() <= 7
    assert np.all(xq == np.round(xq))


# ----------------------------- reconstruct ---------------------------------

def _scales_with_outliers(d=64, outliers=(5, 20), mag=8.0):
    s = RNG.uniform(0.5, 1.5, size=d).astype(np.float32)
    for o in outliers:
        s[o] = mag
    return s


def test_split_threshold_eq6():
    s = np.asarray([1.0, 2.0, 3.0, 4.0], np.float32)
    assert RC.split_threshold(s, 0.0) == pytest.approx(2.5)
    assert RC.split_threshold(s, 2.0) == pytest.approx(2.5 + 2 * np.std(s))


def test_reconstruct_invariants():
    s = _scales_with_outliers()
    hd = RNG.uniform(0.1, 1.0, size=64)
    r = RC.reconstruct(s, hd, alpha=2.0)
    assert len(r.recon_idx) == 64 and len(r.fold_scale) == 64
    assert np.all(r.fold_scale <= r.threshold + 1e-5)
    assert set(r.pruned) & set(r.strong) == set()
    # split parts of each strong channel sum back to its scale
    for k in r.strong:
        parts = r.fold_scale[r.recon_idx == k]
        assert parts.sum() == pytest.approx(s[k], rel=1e-5)
    # non-strong kept channels keep their scale
    for i, src in enumerate(r.recon_idx):
        if src not in set(r.strong):
            assert r.fold_scale[i] == pytest.approx(s[src], rel=1e-6)


def test_reconstruct_output_equivalence():
    """Folded+reconstructed GEMM equals original QSM GEMM up to pruning."""
    d, j = 64, 48
    s = _scales_with_outliers()
    hd = RNG.uniform(0.1, 1.0, size=d)
    r = RC.reconstruct(s, hd, alpha=2.0)
    w = RNG.normal(size=(d, j)).astype(np.float32)
    xq = RNG.integers(-7, 8, size=(16, d)).astype(np.float32)
    full = (xq * s) @ w  # exact per-channel dequant GEMM
    recon_out = r.apply_to_activation(xq) @ r.apply_to_weight(w)
    # identical except the pruned channels' contribution
    pruned_contrib = (xq[:, r.pruned] * s[r.pruned]) @ w[r.pruned]
    np.testing.assert_allclose(recon_out, full - pruned_contrib, rtol=1e-4,
                               atol=1e-4)


def test_neighbor_cases():
    # case 1: adjacent outliers 5,6 -> neighbors 4,7 (no duplicates)
    assert set(RC.neighbor_channels([5, 6], 64)) == {4, 7}
    # case 2: outliers 5,7 with one normal channel between -> 6 counted once
    assert sorted(RC.neighbor_channels([5, 7], 64)) == [4, 6, 8]
    # case 3: boundary outliers
    assert set(RC.neighbor_channels([0], 64)) == {1}
    assert set(RC.neighbor_channels([63], 64)) == {62}


def test_choose_pruned_schemes():
    hd = np.arange(64, dtype=np.float64)  # importance = channel index
    # N > M: prune least-important neighbors only
    pr = RC.choose_pruned([10, 30], hd, 2)
    assert pr == [9, 11]
    # N == M
    pr = RC.choose_pruned([10, 30], hd, 4)
    assert sorted(pr) == [9, 11, 29, 31]
    # N < M: all neighbors + least-important others
    pr = RC.choose_pruned([10], hd, 4)
    assert set(pr) >= {9, 11}
    assert len(pr) == 4 and 0 in pr and 1 in pr


def test_identity_reconstruction_noop():
    s = _scales_with_outliers()
    r = RC.identity_reconstruction(s)
    x = RNG.normal(size=(4, 64)).astype(np.float32)
    np.testing.assert_array_equal(r.apply_to_activation(x), x)
    w = RNG.normal(size=(64, 8)).astype(np.float32)
    np.testing.assert_allclose(r.apply_to_weight(w), s[:, None] * w,
                               rtol=1e-6)


# ----------------------------- clipping ------------------------------------

def test_clip_ratios_in_grid():
    x = RNG.normal(size=(256, 32)).astype(np.float32)
    x[:, 3] *= 15
    am = np.abs(x).max(axis=0)
    w = RNG.normal(size=(32, 16)).astype(np.float32)
    r_ad = CL.adaptive_channel_clip(x, am, w)
    r_ch = CL.channel_clip_act_only(x, am)
    for r in (r_ad, r_ch):
        assert np.all((r >= 0.5 - 1e-6) & (r <= 1.0 + 1e-6))


def test_heavy_tail_channel_gets_clipped():
    """A channel with a moderate spike should clip below 1.0: sacrificing
    the one spike buys resolution for the entire body of the channel."""
    x = RNG.normal(size=(512, 8)).astype(np.float32)
    x[0, 2] = 12.0
    am = np.abs(x).max(axis=0)
    r = CL.channel_clip_act_only(x, am)
    assert r[2] < 1.0
    # and picking that ratio really does reduce the round-off error
    qa = 7
    def err(ratio):
        s = am[2] * ratio / qa
        xq = np.clip(np.round(x[:, 2] / s), -qa, qa)
        return float(np.sum((xq * s - x[:, 2]) ** 2))
    assert err(r[2]) <= err(1.0)


def test_uniform_token_clip_improves_output_mse():
    x = RNG.standard_t(df=2, size=(512, 32)).astype(np.float32)  # heavy tails
    w = RNG.normal(size=(32, 16)).astype(np.float32)
    r = CL.uniform_token_clip(x, w)
    assert 0.5 <= r <= 1.0

    def out_err(clip):
        return float(np.sum(
            (Q.per_token_dynamic_matmul(x, Q.quantize_weight(w), clip=clip)
             - x @ w) ** 2))
    assert out_err(r) <= out_err(1.0) + 1e-3


# ----------------------------- gptq -----------------------------------------

def _correlated_inputs(s=512, n=64):
    basis = RNG.normal(size=(8, n)).astype(np.float32)
    z = RNG.normal(size=(s, 8)).astype(np.float32)
    return z @ basis + 0.1 * RNG.normal(size=(s, n)).astype(np.float32)


@pytest.mark.parametrize("sym,group", [(True, 0), (False, 0), (True, 16)])
def test_gptq_beats_rtn_on_output_error(sym, group):
    x = _correlated_inputs()
    w = RNG.normal(size=(64, 32)).astype(np.float32)
    ref = x @ w
    q_rtn = Q.quantize_weight(w, bits=3, sym=sym, group=group)
    q_gptq = gptq_quantize(w, x, bits=3, sym=sym, group=group)
    e_rtn = float(np.sum((x @ q_rtn.dequant() - ref) ** 2))
    e_gptq = float(np.sum((x @ q_gptq.dequant() - ref) ** 2))
    assert e_gptq < e_rtn


def test_gptq_handles_dead_inputs():
    x = _correlated_inputs()
    x[:, 7] = 0.0
    w = RNG.normal(size=(64, 16)).astype(np.float32)
    qw = gptq_quantize(w, x, bits=4)
    assert np.isfinite(qw.dequant()).all()


def test_gptq_integer_range():
    x = _correlated_inputs()
    w = RNG.normal(size=(64, 16)).astype(np.float32)
    qw = gptq_quantize(w, x, bits=4)
    assert qw.wq.min() >= -7 and qw.wq.max() <= 7


# ----------------------------- lora ----------------------------------------

def test_compensation_reduces_output_error():
    x = _correlated_inputs()
    w = RNG.normal(size=(64, 32)).astype(np.float32)

    def quant(mat):
        return Q.quantize_weight(mat, bits=3)

    base = quant(w)
    e_base = float(np.sum((x @ base.dequant() - x @ w) ** 2))
    qw, ab = compensate(w, x, x, w, quant, rank=8, rounds=3)
    e_comp = float(np.sum((x @ qw.dequant() - x @ w) ** 2))
    assert e_comp <= e_base  # never worse (best-round early stopping)
    assert np.linalg.matrix_rank(ab) <= 8 * 3  # rank accumulates per round


# ----------------------------- hadamard ------------------------------------

@pytest.mark.parametrize("d", [64, 128, 192, 512])
def test_fwht_matches_dense_matrix(d):
    x = RNG.normal(size=(4, d)).astype(np.float32)
    hm = H.hadamard_matrix(d)
    np.testing.assert_allclose(H.fwht_block64(x), x @ hm.T, atol=1e-4)


def test_fwht_orthogonal_and_involutive():
    x = RNG.normal(size=(8, 128)).astype(np.float32)
    y = H.fwht_block64(x)
    np.testing.assert_allclose(np.linalg.norm(y, axis=1),
                               np.linalg.norm(x, axis=1), rtol=1e-5)
    np.testing.assert_allclose(H.fwht_block64(y), x, atol=1e-4)


def test_online_hadamard_fold_preserves_output():
    x = RNG.normal(size=(16, 128)).astype(np.float32)
    w = RNG.normal(size=(128, 32)).astype(np.float32)
    wf = H.fold_online_hadamard_into_weight(w)
    np.testing.assert_allclose(H.fwht_block64(x) @ wf, x @ w, atol=1e-3)


def test_random_orthogonal_is_orthogonal():
    q = H.random_orthogonal(64, seed=3)
    np.testing.assert_allclose(q @ q.T, np.eye(64), atol=1e-5)


def test_random_hadamard_like_is_orthogonal():
    q = H.random_hadamard_like(128, seed=3)
    np.testing.assert_allclose(q @ q.T, np.eye(128), atol=1e-4)
